//! **ReadBroker** — cross-job shared storage scans (§7.5; OneAccess /
//! RecD-style cross-layer reuse): hundreds of continuous training jobs
//! re-read overlapping partitions and popular features, yet each session
//! privately pays the full Tectonic I/O, decryption, and stripe decode.
//! The broker sits between Master plans and the Tectonic cluster:
//! sessions register their planned (file, stripe) interest, overlapping
//! ranges are coalesced, and each popular stripe is fetched and decoded
//! **once** into a ref-counted, budget-bounded buffer
//! ([`StripeBuffer`]), then served to every session as a shared handle.
//! Per-session semantics — projection, predicate / selection vectors,
//! transform DAG — apply *after* the shared decode, so outputs are
//! byte-identical to private scans while the storage cost is paid once.
//!
//! The default sharing grain is the **column** ([`ColumnBuffer`],
//! served through [`ReadBroker::get_columns`]): the paper's §5–6
//! observation is *feature-level* skew, so per-(file, stripe, column)
//! [`SharedColumn`] payloads let sessions with different projections,
//! predicates, and epochs hit the same cached columns, with live
//! per-feature demand ([`crate::popularity::AccessStats`]) driving
//! admission and eviction instead of pure LRU. The stripe-grain path
//! remains as the `column_sharing = false` ablation.

pub mod buffer;

pub use buffer::{
    ColumnBuffer, ColumnId, ColumnServe, FetchedColumns, FetchedStripe,
    MemoryBudget, ServeOutcome, SharedColumn, StripeBuffer,
};
use buffer::StripeKey;

use crate::data::ColumnarBatch;
use crate::popularity::AccessStats;
use crate::dwrf::plan::COALESCE_WINDOW;
use crate::dwrf::{
    DecodeMode, DedupStripe, DwrfReader, Encoding, FileMeta, IoRange,
    Projection,
};
use crate::metrics::Counter;
use crate::obs::{ObsHandle, Stage};
use crate::schema::FeatureId;
use crate::sync::{lock_or_recover, Mutex};
use crate::tectonic::{Cluster, FileId};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

/// Trace lane for broker-side storage fetches: they run on whichever
/// worker thread lost the single-flight race, so they get their own
/// lane instead of inheriting a worker id (`u32::MAX` is the Master's
/// control-plane lane).
const BROKER_TRACE_LANE: u32 = u32::MAX - 1;

pub type BrokerSessionId = u64;

/// A stripe decoded once and shared across sessions.
#[derive(Clone, Debug)]
pub enum SharedStripe {
    /// Flattened / Map encodings: the full per-row columnar batch.
    Columnar(ColumnarBatch),
    /// Dedup encoding: unique payloads + inverse index, *before*
    /// expansion, so dedup-aware sessions keep their per-unique
    /// transform savings.
    Dedup(DedupStripe),
}

impl SharedStripe {
    /// Approximate resident bytes (budget accounting).
    pub fn mem_bytes(&self) -> u64 {
        match self {
            SharedStripe::Columnar(b) => b.approx_bytes() as u64,
            SharedStripe::Dedup(d) => {
                (d.unique.approx_bytes()
                    + d.inverse.len() * 4
                    + d.labels.len() * 4
                    + d.timestamps.len() * 8) as u64
            }
        }
    }

    /// Materialize this session's per-row view: restrict to the session
    /// projection (the shared decode may carry a wider union of every
    /// registrant's features) and expand Dedup payloads.
    pub fn to_columnar(&self, projection: &Projection) -> ColumnarBatch {
        self.to_columnar_masked(projection, None)
    }

    /// [`SharedStripe::to_columnar`] restricted to `keep` rows (a
    /// session's row-group pruning mask, as stripe-local row indices).
    /// The broker decodes whole stripes — it serves sessions with
    /// *different* predicates — so zone-map pruning applies here, on
    /// each session's own view: pruned rows are dropped at the gather /
    /// expansion step and never materialize into this session's
    /// batches.
    pub fn to_columnar_masked(
        &self,
        projection: &Projection,
        keep: Option<&[u32]>,
    ) -> ColumnarBatch {
        match (self, keep) {
            (SharedStripe::Columnar(b), None) => {
                b.retain_features(|f| projection.contains(f))
            }
            (SharedStripe::Columnar(b), Some(k)) => {
                b.retain_features(|f| projection.contains(f)).gather(k)
            }
            (SharedStripe::Dedup(d), None) => d.project(projection).expand(),
            (SharedStripe::Dedup(d), Some(k)) => {
                d.project(projection).filter_rows(k).expand()
            }
        }
    }

    /// This session's unexpanded dedup view (the dedup-aware worker
    /// path). Errors on non-Dedup payloads.
    pub fn to_dedup(&self, projection: &Projection) -> Result<DedupStripe> {
        match self {
            SharedStripe::Dedup(d) => Ok(d.project(projection)),
            SharedStripe::Columnar(_) => {
                bail!("shared stripe is not Dedup-encoded")
            }
        }
    }
}

/// Result of one stripe serve.
pub struct Served {
    pub stripe: Arc<SharedStripe>,
    /// Whether the payload came from the shared buffer (another session
    /// already paid the fetch + decode).
    pub from_buffer: bool,
    /// Storage bytes this serve fetched (0 on buffer hits).
    pub fetched_bytes: u64,
}

/// Broker-level counters: the cross-job reuse the paper's §5–6 sharing
/// observations are after.
#[derive(Default)]
pub struct BrokerMetrics {
    /// Stripe serves satisfied from the shared buffer.
    pub shared_reads: Counter,
    /// Stripe serves that had to fetch + decode.
    pub broker_misses: Counter,
    /// Storage bytes buffer hits avoided re-reading.
    pub saved_bytes: Counter,
    /// Storage bytes actually fetched through the broker.
    pub fetched_bytes: Counter,
    /// Physical I/Os avoided by per-file read coalescing.
    pub coalesced_ios: Counter,
    /// Column-grain serves satisfied from the shared column cache —
    /// including hits on columns some *wider* projection decoded.
    pub column_hits: Counter,
    /// Columns fetched + decoded through the column-grain path.
    pub column_fetches: Counter,
    /// Storage bytes column hits avoided re-reading (bytes served from
    /// wider cached decodes).
    pub column_saved_bytes: Counter,
}

impl BrokerMetrics {
    /// Fraction of stripe serves satisfied without touching storage.
    pub fn hit_rate(&self) -> f64 {
        let h = self.shared_reads.get() as f64;
        let m = self.broker_misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

struct SessionState {
    projection: HashSet<FeatureId>,
    /// (file → stripes) registered but not yet consumed.
    remaining: HashMap<FileId, BTreeSet<usize>>,
    /// Per-session reuse accounting: serves from the shared buffer vs
    /// serves that had to fetch + decode. This is the per-session hit
    /// rate the Master's autoscaler fuses — a mostly-hitting session
    /// skips fetch+decode and needs fewer workers.
    shared_reads: u64,
    broker_misses: u64,
}

#[derive(Default)]
struct BrokerState {
    next_session: u64,
    sessions: HashMap<BrokerSessionId, SessionState>,
    /// Outstanding registered serves per (file, stripe) — how long a
    /// buffered stripe stays wanted.
    interest: HashMap<StripeKey, usize>,
    /// Union of every registered session's projection, per file: shared
    /// decodes use it so any registrant's view is a restriction of the
    /// buffered payload.
    union_proj: HashMap<FileId, HashSet<FeatureId>>,
    /// Encryption domain (table name) per file, from registration.
    tables: HashMap<FileId, String>,
}

/// The cross-job read broker. One instance serves any number of
/// concurrent sessions over one [`Cluster`].
pub struct ReadBroker {
    cluster: Arc<Cluster>,
    /// One cached footer per file across *all* sessions.
    footers: Mutex<HashMap<FileId, Arc<FileMeta>>>,
    state: Mutex<BrokerState>,
    buffer: StripeBuffer,
    /// Column-grain sibling of `buffer` (the `column_sharing` path);
    /// both charge the same [`MemoryBudget`].
    columns: ColumnBuffer,
    /// Live per-feature demand, fed by column serves; drives the column
    /// cache's admission and eviction order.
    popularity: Arc<AccessStats>,
    pub metrics: BrokerMetrics,
    /// Observability sink for traced sessions: cold-path storage
    /// fetch + decode work records `fetch` spans here. One handle —
    /// the latest traced session to attach wins; buffer hits record
    /// nothing (that's the point of a hit).
    obs: Mutex<Option<ObsHandle>>,
}

/// The `(broker, session id)` pair a [`crate::dpp::Master`] hands its
/// workers so the data plane fetches through the shared path.
#[derive(Clone)]
pub struct BrokerHandle {
    pub broker: Arc<ReadBroker>,
    pub session: BrokerSessionId,
}

impl BrokerHandle {
    /// This session's shared-buffer hit rate (the Master autoscaler's
    /// broker signal).
    pub fn hit_rate(&self) -> f64 {
        self.broker.session_hit_rate(self.session)
    }
}

impl ReadBroker {
    pub fn new(
        cluster: Arc<Cluster>,
        budget: Arc<MemoryBudget>,
    ) -> Arc<ReadBroker> {
        Arc::new(ReadBroker {
            cluster,
            footers: Mutex::new(HashMap::new()),
            state: Mutex::new(BrokerState::default()),
            buffer: StripeBuffer::new(budget.clone()),
            columns: ColumnBuffer::new(budget),
            popularity: Arc::new(AccessStats::default()),
            metrics: BrokerMetrics::default(),
            obs: Mutex::new(None),
        })
    }

    /// The live per-feature demand tracker column serves feed.
    pub fn popularity(&self) -> &Arc<AccessStats> {
        &self.popularity
    }

    /// Attach an observability sink: subsequent cold-path stripe
    /// fetches record `fetch` spans against it.
    pub fn attach_obs(&self, h: ObsHandle) {
        *lock_or_recover(&self.obs, "broker obs") = Some(h);
    }

    /// A broker with its own private stripe-buffer budget. To share one
    /// pool with a [`crate::dpp::TensorCache`], build the
    /// [`MemoryBudget`] first and pass it to both.
    pub fn with_budget_bytes(
        cluster: Arc<Cluster>,
        bytes: u64,
    ) -> Arc<ReadBroker> {
        Self::new(cluster, MemoryBudget::new(bytes))
    }

    /// The budget broker buffers charge against.
    pub fn budget(&self) -> Arc<MemoryBudget> {
        self.buffer.budget().clone()
    }

    /// Stripes currently resident in the shared buffer.
    pub fn buffered_stripes(&self) -> usize {
        self.buffer.len()
    }

    /// Columns currently resident in the shared column cache.
    pub fn buffered_columns(&self) -> usize {
        self.columns.len()
    }

    /// Fetch-once footer cache: control-plane I/O is shared across
    /// sessions exactly like data-plane stripes.
    pub fn footer(&self, file: FileId) -> Result<Arc<FileMeta>> {
        if let Some(m) =
            lock_or_recover(&self.footers, "broker footers").get(&file)
        {
            return Ok(m.clone());
        }
        let meta =
            Arc::new(crate::dpp::Master::fetch_meta(&self.cluster, file)?);
        let mut cached = lock_or_recover(&self.footers, "broker footers");
        Ok(cached.entry(file).or_insert(meta).clone())
    }

    /// Register a session's planned interest: its projection joins the
    /// per-file union the shared decode uses, and each (file, stripe)
    /// interest count decides how long buffered stripes stay resident.
    pub fn register(
        &self,
        table: &str,
        projection: &Projection,
        interest: HashMap<FileId, Vec<usize>>,
    ) -> BrokerSessionId {
        let mut st = lock_or_recover(&self.state, "broker state");
        let id = st.next_session;
        st.next_session += 1;
        let proj: HashSet<FeatureId> = projection.iter().copied().collect();
        let mut remaining: HashMap<FileId, BTreeSet<usize>> = HashMap::new();
        for (file, stripes) in interest {
            st.tables.insert(file, table.to_string());
            st.union_proj
                .entry(file)
                .or_default()
                .extend(proj.iter().copied());
            let set: BTreeSet<usize> = stripes.into_iter().collect();
            for &s in &set {
                *st.interest.entry((file, s)).or_insert(0) += 1;
            }
            remaining.insert(file, set);
        }
        st.sessions.insert(
            id,
            SessionState {
                projection: proj,
                remaining,
                shared_reads: 0,
                broker_misses: 0,
            },
        );
        id
    }

    /// Fraction of this session's stripe serves satisfied from the
    /// shared buffer (0.0 before any serve, or for unknown sessions).
    /// Unlike [`BrokerMetrics::hit_rate`], which aggregates across every
    /// attached session, this is the per-session scaling signal.
    pub fn session_hit_rate(&self, session: BrokerSessionId) -> f64 {
        let st = lock_or_recover(&self.state, "broker state");
        st.sessions.get(&session).map_or(0.0, |s| {
            let total = s.shared_reads + s.broker_misses;
            if total == 0 {
                0.0
            } else {
                s.shared_reads as f64 / total as f64
            }
        })
    }

    /// Drop a session's outstanding interest; stripes nobody else wants
    /// any more are released from the buffer immediately.
    pub fn unregister(&self, session: BrokerSessionId) {
        let mut st = lock_or_recover(&self.state, "broker state");
        let Some(sess) = st.sessions.remove(&session) else {
            return;
        };
        let mut freed = Vec::new();
        for (file, stripes) in sess.remaining {
            for s in stripes {
                let key = (file, s);
                if let Some(n) = st.interest.get_mut(&key) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        st.interest.remove(&key);
                        freed.push(key);
                    }
                }
            }
        }
        drop(st);
        for key in freed {
            self.buffer.release(key);
            self.columns.release_stripe(key);
        }
    }

    /// Serve one stripe to a registered session: fetched + decoded once
    /// (with the union projection, through coalesced per-file I/O) on
    /// first demand, then served from memory to every later session.
    /// The caller applies its own predicate / selection / transforms
    /// downstream.
    pub fn get_stripe(
        &self,
        session: BrokerSessionId,
        file: FileId,
        stripe: usize,
    ) -> Result<Served> {
        let key: StripeKey = (file, stripe);
        let (needed, union, table, consumed, others) = {
            let mut st = lock_or_recover(&self.state, "broker state");
            let sess = st
                .sessions
                .get_mut(&session)
                .context("unknown broker session")?;
            let needed: Vec<FeatureId> =
                sess.projection.iter().copied().collect();
            let consumed = sess
                .remaining
                .get_mut(&file)
                .is_some_and(|s| s.remove(&stripe));
            // The union must cover this serve even for stripes the
            // session never registered (e.g. a requeued split).
            let u = st.union_proj.entry(file).or_default();
            u.extend(needed.iter().copied());
            let union: Vec<FeatureId> = u.iter().copied().collect();
            // Registered serves still expected from *other* sessions.
            // The interest count is decremented only after the serve
            // completes, so concurrent sessions racing on the same
            // stripe all see each other as outstanding — whichever one
            // loads caches the payload for the rest (single-flight
            // holds no matter how the lock acquisitions interleave).
            let count = st.interest.get(&key).copied().unwrap_or(0);
            let others = if consumed {
                count.saturating_sub(1)
            } else {
                count
            };
            let table = st
                .tables
                .get(&file)
                .cloned()
                .unwrap_or_else(|| "default".to_string());
            (needed, union, table, consumed, others)
        };

        let meta = self.footer(file)?;
        if stripe >= meta.stripes.len() {
            bail!("stripe {stripe} out of range for {file:?}");
        }
        let union_proj = Projection::new(union);
        let obs = lock_or_recover(&self.obs, "broker obs").clone();
        let fetch = || -> Result<FetchedStripe> {
            let t_fetch = Instant::now();
            let reader = DwrfReader::from_meta((*meta).clone(), &table);
            // Plan one I/O per wanted stream; the cluster merges them
            // (per-file read coalescing) before touching devices.
            let plan = reader.plan_stripes(&union_proj, None, stripe, 1);
            let extents: Vec<IoRange> = plan
                .stripes
                .iter()
                .flat_map(|sp| sp.ios.iter().copied())
                .collect();
            let n_extents = extents.len();
            let (bufs, n_ios) = self.cluster.execute_ios_merged(
                file,
                &extents,
                Some(COALESCE_WINDOW),
            )?;
            let fetched_bytes = bufs.bytes();
            let mode = DecodeMode { fast: true };
            let payload = match reader.meta.encoding {
                Encoding::Dedup => SharedStripe::Dedup(
                    reader
                        .decode_stripe_dedup(stripe, &bufs, &union_proj, mode)?,
                ),
                _ => SharedStripe::Columnar(reader.decode_stripe_columnar(
                    stripe,
                    &bufs,
                    &union_proj,
                    mode,
                )?),
            };
            if let Some(h) = &obs {
                h.span(
                    BROKER_TRACE_LANE,
                    stripe as u64,
                    Stage::Fetch,
                    t_fetch,
                );
            }
            Ok(FetchedStripe {
                stripe: payload,
                proj: union_proj.iter().copied().collect(),
                fetched_bytes,
                extents: n_extents,
                ios: n_ios,
            })
        };
        let outcome = match self.buffer.serve(key, &needed, others, fetch) {
            Ok(o) => o,
            Err(e) => {
                if consumed {
                    // Roll back the consumption so a retried (requeued)
                    // split serves — and settles its interest — like a
                    // normal registered serve, and unregistration still
                    // accounts for this stripe.
                    let mut st = lock_or_recover(&self.state, "broker state");
                    if let Some(sess) = st.sessions.get_mut(&session) {
                        sess.remaining
                            .entry(file)
                            .or_default()
                            .insert(stripe);
                    }
                }
                return Err(e);
            }
        };
        // Settle interest now that the serve is done: the consumer that
        // takes the count to zero releases the buffered entry, however
        // the concurrent serves interleaved.
        let was_hit = matches!(outcome, ServeOutcome::Hit { .. });
        {
            let mut st = lock_or_recover(&self.state, "broker state");
            if let Some(sess) = st.sessions.get_mut(&session) {
                if was_hit {
                    sess.shared_reads += 1;
                } else {
                    sess.broker_misses += 1;
                }
            }
            if consumed {
                if let Some(n) = st.interest.get_mut(&key) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        st.interest.remove(&key);
                    }
                }
            }
            let wanted = st.interest.contains_key(&key);
            drop(st);
            if !wanted {
                self.buffer.release(key);
            }
        }
        match outcome {
            ServeOutcome::Hit {
                payload,
                saved_bytes,
            } => {
                self.metrics.shared_reads.inc();
                self.metrics.saved_bytes.add(saved_bytes);
                Ok(Served {
                    stripe: payload,
                    from_buffer: true,
                    fetched_bytes: 0,
                })
            }
            ServeOutcome::Fetched {
                payload,
                fetched_bytes,
                extents,
                ios,
            } => {
                self.metrics.broker_misses.inc();
                self.metrics.fetched_bytes.add(fetched_bytes);
                self.metrics
                    .coalesced_ios
                    .add(extents.saturating_sub(ios) as u64);
                Ok(Served {
                    stripe: payload,
                    from_buffer: false,
                    fetched_bytes,
                })
            }
        }
    }

    /// Serve one stripe to a registered session at *column* grain: each
    /// of the session's projected columns (plus the stripe's row meta)
    /// is fetched and decoded at most once fleet-wide, whatever wider or
    /// narrower projections first brought it in. Cached columns from any
    /// earlier decode are reused directly; only the still-missing
    /// columns are fetched. The caller reassembles its batch with
    /// [`DwrfReader::assemble_columnar`] / [`DwrfReader::assemble_dedup`]
    /// and applies predicate / selection / transforms downstream —
    /// byte-identical to a private scan. Not available for `Map`
    /// encoding (row-wise layout; callers fall back to
    /// [`ReadBroker::get_stripe`]).
    pub fn get_columns(
        &self,
        session: BrokerSessionId,
        file: FileId,
        stripe: usize,
    ) -> Result<ServedColumns> {
        let key: StripeKey = (file, stripe);
        let (feats, table, consumed, others) = {
            let mut st = lock_or_recover(&self.state, "broker state");
            let sess = st
                .sessions
                .get_mut(&session)
                .context("unknown broker session")?;
            let feats: Vec<FeatureId> =
                sess.projection.iter().copied().collect();
            let consumed = sess
                .remaining
                .get_mut(&file)
                .is_some_and(|s| s.remove(&stripe));
            // Same outstanding-interest rule as the stripe path: the
            // count settles only after the serve, so racing sessions see
            // each other and the loader caches for the rest.
            let count = st.interest.get(&key).copied().unwrap_or(0);
            let others = if consumed {
                count.saturating_sub(1)
            } else {
                count
            };
            let table = st
                .tables
                .get(&file)
                .cloned()
                .unwrap_or_else(|| "default".to_string());
            (feats, table, consumed, others)
        };

        let meta = self.footer(file)?;
        if stripe >= meta.stripes.len() {
            bail!("stripe {stripe} out of range for {file:?}");
        }
        if meta.encoding == Encoding::Map {
            bail!("column-grain serve on Map-encoded {file:?}");
        }
        let reader = DwrfReader::from_meta((*meta).clone(), &table);
        let proj = Projection::new(feats.iter().copied());
        let (dense, sparse) = reader.projected_columns(stripe, &proj);
        let mut needed: Vec<ColumnId> = vec![ColumnId::Meta];
        needed.extend(dense.into_iter().map(ColumnId::Feature));
        needed.extend(sparse.into_iter().map(ColumnId::Feature));

        let obs = lock_or_recover(&self.obs, "broker obs").clone();
        // Row meta backs every projection of the stripe: pin it above
        // any feature column in the eviction order.
        let demand = |c: ColumnId| match c {
            ColumnId::Meta => f64::MAX,
            ColumnId::Feature(f) => self.popularity.demand(f),
        };
        let fetch = |missing: &[ColumnId]| -> Result<FetchedColumns> {
            let t_fetch = Instant::now();
            let extents = reader.column_ios(stripe, missing)?;
            let n_extents = extents.len();
            let (bufs, n_ios) = self.cluster.execute_ios_merged(
                file,
                &extents,
                Some(COALESCE_WINDOW),
            )?;
            let fetched_bytes = bufs.bytes();
            let cols = reader.decode_columns(
                stripe,
                &bufs,
                missing,
                DecodeMode { fast: true },
            )?;
            if let Some(h) = &obs {
                h.span(
                    BROKER_TRACE_LANE,
                    stripe as u64,
                    Stage::Fetch,
                    t_fetch,
                );
            }
            Ok(FetchedColumns {
                cols,
                fetched_bytes,
                extents: n_extents,
                ios: n_ios,
            })
        };
        let outcome =
            match self.columns.serve(key, &needed, others, &demand, fetch) {
                Ok(o) => o,
                Err(e) => {
                    if consumed {
                        // Roll back the consumption (same retry contract
                        // as the stripe path).
                        let mut st =
                            lock_or_recover(&self.state, "broker state");
                        if let Some(sess) = st.sessions.get_mut(&session) {
                            sess.remaining
                                .entry(file)
                                .or_default()
                                .insert(stripe);
                        }
                    }
                    return Err(e);
                }
            };
        // Feed the live demand tracker: every column this session
        // demanded counts, hit or miss — demand is about what sessions
        // *read*, not what storage served.
        for (c, payload) in &outcome.cols {
            if let ColumnId::Feature(f) = c {
                self.popularity.record_serve(*f, payload.mem_bytes());
            }
        }
        let fully_cached = outcome.fetched_cols == 0;
        {
            let mut st = lock_or_recover(&self.state, "broker state");
            if let Some(sess) = st.sessions.get_mut(&session) {
                if fully_cached {
                    sess.shared_reads += 1;
                } else {
                    sess.broker_misses += 1;
                }
            }
            if consumed {
                if let Some(n) = st.interest.get_mut(&key) {
                    *n = n.saturating_sub(1);
                    if *n == 0 {
                        st.interest.remove(&key);
                    }
                }
            }
            let wanted = st.interest.contains_key(&key);
            drop(st);
            if !wanted {
                self.columns.release_stripe(key);
            }
        }
        self.metrics.column_hits.add(outcome.hits as u64);
        self.metrics.column_fetches.add(outcome.fetched_cols as u64);
        self.metrics.column_saved_bytes.add(outcome.saved_bytes);
        if fully_cached {
            self.metrics.shared_reads.inc();
            self.metrics.saved_bytes.add(outcome.saved_bytes);
        } else {
            self.metrics.broker_misses.inc();
            self.metrics.fetched_bytes.add(outcome.fetched_bytes);
            self.metrics
                .coalesced_ios
                .add(outcome.extents.saturating_sub(outcome.ios) as u64);
        }
        Ok(ServedColumns {
            cols: outcome.cols,
            from_buffer: fully_cached,
            hits: outcome.hits,
            fetched_cols: outcome.fetched_cols,
            fetched_bytes: outcome.fetched_bytes,
        })
    }
}

/// Result of one column-grain serve: the session's projected columns
/// (plus row meta), each an `Arc` into the shared cache.
pub struct ServedColumns {
    pub cols: Vec<(ColumnId, Arc<SharedColumn>)>,
    /// Whether *every* column came from the shared cache.
    pub from_buffer: bool,
    /// Columns served from cache / fetched by this serve.
    pub hits: usize,
    pub fetched_cols: usize,
    /// Storage bytes this serve fetched (0 when fully cached).
    pub fetched_bytes: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dpp::Master;
    use crate::dwrf::WriterOptions;
    use crate::tectonic::ClusterConfig;
    use crate::warehouse::Catalog;

    fn setup() -> (Arc<Cluster>, String, Vec<FileId>, Vec<FeatureId>) {
        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        }));
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            7,
        )
        .unwrap();
        let files: Vec<FileId> = catalog
            .get(&h.table_name)
            .unwrap()
            .partitions
            .iter()
            .map(|p| p.file)
            .collect();
        let feats: Vec<FeatureId> =
            h.schema.features.iter().map(|f| f.id).collect();
        (cluster, h.table_name, files, feats)
    }

    fn interest_for(file: FileId, stripes: &[usize]) -> HashMap<FileId, Vec<usize>> {
        let mut m = HashMap::new();
        m.insert(file, stripes.to_vec());
        m
    }

    /// The private (non-broker) decode of one stripe under `proj`.
    fn private_decode(
        cluster: &Cluster,
        table: &str,
        file: FileId,
        stripe: usize,
        proj: &Projection,
    ) -> ColumnarBatch {
        let meta = Master::fetch_meta(cluster, file).unwrap();
        let reader = DwrfReader::from_meta(meta, table);
        let plan = reader.plan_stripes(proj, None, stripe, 1);
        let bufs = cluster
            .execute_ios(file, &plan.stripes[0].ios)
            .unwrap();
        reader
            .decode_stripe_columnar(stripe, &bufs, proj, DecodeMode::default())
            .unwrap()
    }

    #[test]
    fn footer_cached_once_across_sessions() {
        let (cluster, _, files, _) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 1 << 20);
        cluster.reset_stats();
        let m1 = broker.footer(files[0]).unwrap();
        let reads = cluster.stats().reads;
        assert!(reads > 0, "first footer fetch hits storage");
        let m2 = broker.footer(files[0]).unwrap();
        assert_eq!(cluster.stats().reads, reads, "second fetch is cached");
        assert!(Arc::ptr_eq(&m1, &m2));
    }

    #[test]
    fn stripe_fetched_once_then_served_shared_and_released() {
        let (cluster, table, files, feats) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let proj = Projection::new(feats.iter().copied());
        let s1 = broker.register(&table, &proj, interest_for(files[0], &[0]));
        let s2 = broker.register(&table, &proj, interest_for(files[0], &[0]));
        let a = broker.get_stripe(s1, files[0], 0).unwrap();
        assert!(!a.from_buffer);
        assert!(a.fetched_bytes > 0);
        assert_eq!(broker.buffered_stripes(), 1);
        let b = broker.get_stripe(s2, files[0], 0).unwrap();
        assert!(b.from_buffer);
        assert_eq!(b.fetched_bytes, 0);
        // Last interested session consumed it: memory released.
        drop((a, b));
        assert_eq!(broker.buffered_stripes(), 0);
        assert_eq!(broker.budget().used(), 0);
        assert_eq!(broker.metrics.shared_reads.get(), 1);
        assert_eq!(broker.metrics.broker_misses.get(), 1);
        assert!(broker.metrics.saved_bytes.get() > 0);
        assert!((broker.metrics.hit_rate() - 0.5).abs() < 1e-9);
        // Per-session attribution: s1 paid the miss, s2 rode the buffer.
        assert!((broker.session_hit_rate(s1) - 0.0).abs() < 1e-9);
        assert!((broker.session_hit_rate(s2) - 1.0).abs() < 1e-9);
        assert_eq!(broker.session_hit_rate(9999), 0.0, "unknown session");
    }

    #[test]
    fn session_views_match_private_decodes() {
        let (cluster, table, files, feats) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let full = Projection::new(feats.iter().copied());
        let narrow = Projection::new(feats.iter().take(4).copied());
        // Register the wide session first so the union covers both.
        let s1 = broker.register(&table, &full, interest_for(files[0], &[0]));
        let s2 =
            broker.register(&table, &narrow, interest_for(files[0], &[0]));
        let a = broker.get_stripe(s1, files[0], 0).unwrap();
        let b = broker.get_stripe(s2, files[0], 0).unwrap();
        assert!(b.from_buffer, "narrow view restricts the shared decode");
        assert_eq!(
            a.stripe.to_columnar(&full),
            private_decode(&cluster, &table, files[0], 0, &full)
        );
        assert_eq!(
            b.stripe.to_columnar(&narrow),
            private_decode(&cluster, &table, files[0], 0, &narrow)
        );
    }

    #[test]
    fn projection_widening_refetches() {
        let (cluster, table, files, feats) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let narrow = Projection::new(feats.iter().take(2).copied());
        let full = Projection::new(feats.iter().copied());
        // Two narrow sessions keep the narrow decode buffered...
        let s1 =
            broker.register(&table, &narrow, interest_for(files[0], &[0]));
        let _s1b =
            broker.register(&table, &narrow, interest_for(files[0], &[0]));
        let a = broker.get_stripe(s1, files[0], 0).unwrap();
        assert!(!a.from_buffer);
        assert_eq!(broker.buffered_stripes(), 1);
        // ...then a wider session registers: the buffered narrow decode
        // cannot serve it — the broker refetches with the new union.
        let s2 = broker.register(&table, &full, interest_for(files[0], &[0]));
        let b = broker.get_stripe(s2, files[0], 0).unwrap();
        assert!(!b.from_buffer, "narrow payload insufficient; refetched");
        assert_eq!(
            b.stripe.to_columnar(&full),
            private_decode(&cluster, &table, files[0], 0, &full)
        );
        // The refetched (wide) payload now serves the remaining narrow
        // session from the buffer.
        let c = broker.get_stripe(_s1b, files[0], 0).unwrap();
        assert!(c.from_buffer);
        assert_eq!(
            c.stripe.to_columnar(&narrow),
            private_decode(&cluster, &table, files[0], 0, &narrow)
        );
    }

    #[test]
    fn unknown_session_errors() {
        let (cluster, _, files, _) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster, 1 << 20);
        assert!(broker.get_stripe(999, files[0], 0).is_err());
    }

    #[test]
    fn unregister_releases_unconsumed_interest() {
        let (cluster, table, files, feats) = setup();
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let proj = Projection::new(feats.iter().copied());
        let s1 = broker.register(&table, &proj, interest_for(files[0], &[0]));
        let s2 = broker.register(&table, &proj, interest_for(files[0], &[0]));
        let a = broker.get_stripe(s1, files[0], 0).unwrap();
        drop(a);
        assert_eq!(broker.buffered_stripes(), 1, "kept for s2");
        broker.unregister(s2);
        assert_eq!(broker.buffered_stripes(), 0, "s2 gone, buffer freed");
        assert_eq!(broker.budget().used(), 0);
    }
}
