//! `dsi` — the leader binary: paper experiment drivers, a DPP session
//! runner, and the PJRT-backed DLRM training loop.
//!
//! ```text
//! dsi paper --exp table12 [--seed 42] [--scale tiny|standard|bench] [--json out.json]
//! dsi paper --exp all
//! dsi session --rm rm1 --workers 4 --clients 2 [--autoscale]
//!             [--trace trace.json] [--telemetry telemetry.json]
//! dsi train --steps 200 [--seed 7]
//! dsi info
//! ```

use anyhow::{bail, Context, Result};
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::dpp::{Session, SessionConfig, SessionSpec};
use dsi::dwrf::WriterOptions;
use dsi::paper;
use dsi::runtime::{artifacts_available, artifacts_dir, DlrmBatch, DlrmRuntime};
use dsi::util::cli::Args;
use dsi::util::rng::Pcg32;

fn main() {
    let args = Args::parse();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn scale_from(args: &Args) -> SimScale {
    match args.get_or("scale", "standard") {
        "tiny" => SimScale::tiny(),
        "bench" => SimScale::bench(),
        _ => SimScale::standard(),
    }
}

fn rm_from(args: &Args) -> Result<RmConfig> {
    Ok(match args.get_or("rm", "rm1").to_lowercase().as_str() {
        "rm1" => RmConfig::get(RmId::Rm1),
        "rm2" => RmConfig::get(RmId::Rm2),
        "rm3" => RmConfig::get(RmId::Rm3),
        other => bail!("unknown model '{other}' (rm1|rm2|rm3)"),
    })
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("paper") => cmd_paper(args),
        Some("session") => cmd_session(args),
        Some("train") => cmd_train(args),
        Some("info") | None => cmd_info(),
        Some(other) => bail!("unknown subcommand '{other}'"),
    }
}

fn cmd_info() -> Result<()> {
    println!("dsi — Meta DSI pipeline reproduction (Zhao et al., ISCA '22)");
    println!("subcommands: paper | session | train | info");
    println!("experiments: {}", paper::ALL_EXPERIMENTS.join(", "));
    println!(
        "artifacts: {} ({})",
        artifacts_dir().display(),
        if artifacts_available() {
            "present"
        } else {
            "missing — run `make artifacts`"
        }
    );
    Ok(())
}

fn cmd_paper(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all");
    let seed = args.get_u64("seed", 42);
    let scale = scale_from(args);
    let json = if exp == "all" {
        paper::run_all(&scale, seed)?
    } else {
        paper::run(exp, &scale, seed)?
    };
    if let Some(path) = args.get("json").filter(|s| !s.is_empty()) {
        std::fs::write(path, json.to_string_pretty())
            .with_context(|| format!("write {path}"))?;
        println!("\nwrote {path}");
    }
    Ok(())
}

fn cmd_session(args: &Args) -> Result<()> {
    use dsi::datagen::build_dataset;
    use dsi::tectonic::{Cluster, ClusterConfig};
    use dsi::transforms::dag::session_dag;
    use dsi::warehouse::Catalog;
    use std::sync::Arc;

    let rm = rm_from(args)?;
    let scale = scale_from(args);
    let seed = args.get_u64("seed", 42);
    let mut rng = Pcg32::new(seed);

    println!("building {} dataset (scale: {scale:?}) ...", rm.id.name());
    let cluster = Arc::new(Cluster::new(ClusterConfig::default()));
    let catalog = Catalog::new();
    let handle = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions::default(),
        seed,
    )?;
    let take = (handle.schema.features.len() as f64 * rm.frac_feats_used())
        .round()
        .max(4.0) as usize;
    let projection =
        handle
            .schema
            .sample_projection(&mut rng, take, rm.popularity_zipf_s);
    let dag = session_dag(&mut rng, &rm, &handle.schema, &projection);
    let mut spec =
        SessionSpec::from_dag(&handle.table_name, 0, u32::MAX, dag, 64);

    let trace_path = args.get("trace").filter(|s| !s.is_empty());
    let telemetry_path = args.get("telemetry").filter(|s| !s.is_empty());
    if trace_path.is_some() || telemetry_path.is_some() {
        spec.pipeline.tracing = true;
    }
    let cfg = SessionConfig {
        initial_workers: args.get_u64("workers", 2) as usize,
        max_workers: args.get_u64("max-workers", 8) as usize,
        clients: args.get_u64("clients", 1) as usize,
        autoscale_every: if args.has("autoscale") {
            Some(std::time::Duration::from_millis(5))
        } else {
            None
        },
        telemetry_every: telemetry_path
            .map(|_| std::time::Duration::from_millis(20)),
        ..Default::default()
    };
    println!(
        "running DPP session: {} workers (max {}), {} clients ...",
        cfg.initial_workers, cfg.max_workers, cfg.clients
    );
    let report = Session::run(&catalog, &cluster, spec, &cfg)?;
    println!("rows delivered     : {}", report.rows_delivered);
    println!("batches delivered  : {}", report.batches_delivered);
    println!("wall time          : {:.3}s", report.wall_secs);
    println!("throughput         : {:.0} rows/s", report.rows_per_sec);
    println!("worker QPS (wall)  : {:.0} rows/s", report.worker_qps);
    println!("peak workers       : {}", report.peak_workers);
    println!(
        "worker pool        : {:.2} worker-secs ({} retired, {} final)",
        report.worker_pool_secs, report.workers_retired, report.final_workers
    );
    println!(
        "client loading     : {:.2} MB ({:.1} MB/s)",
        report.client_rx_bytes as f64 / 1e6,
        report.client_rx_bytes as f64 / 1e6 / report.wall_secs
    );
    println!(
        "storage            : {} reads, {} seeks, {:.2} MB, {:.1} MB/s per \
         device-sec",
        report.storage_reads,
        report.storage_seeks,
        report.storage_bytes_read as f64 / 1e6,
        report.storage_mbps()
    );
    let att = &report.stall_attribution;
    println!(
        "client stall       : {:.3}s [{}] storage {:.3}s / decode {:.3}s \
         / transform {:.3}s / starved {:.3}s",
        report.client_stall_secs,
        att.dominant(),
        att.storage_secs,
        att.decode_secs,
        att.transform_secs,
        att.starved_secs
    );
    if let Some(path) = trace_path {
        let obs = report.obs.as_ref().expect("traced session has a sink");
        write_chrome_trace(obs, path)?;
        println!("trace              : wrote {path}");
    }
    if let Some(path) = telemetry_path {
        let obs = report.obs.as_ref().expect("traced session has a sink");
        let mut j = dsi::util::json::Json::obj();
        j.set("stage_histograms", obs.histograms_json())
            .set("stall_attribution", report.stall_attribution.to_json());
        if let Some(tel) = &report.telemetry {
            j.set("telemetry", tel.to_json());
        }
        std::fs::write(path, j.to_string_pretty())
            .with_context(|| format!("write {path}"))?;
        println!("telemetry          : wrote {path}");
    }
    Ok(())
}

/// Export + self-check: serialize the Chrome trace, re-parse it, and
/// require at least one complete (`"ph": "X"`) span before writing —
/// an empty or malformed trace is an error, not a silent artifact.
fn write_chrome_trace(obs: &dsi::obs::Obs, path: &str) -> Result<()> {
    use dsi::util::json::Json;
    let text = obs.chrome_trace().to_string_pretty();
    let parsed = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("trace JSON malformed: {e}"))?;
    let spans = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map_or(0, |evs| {
            evs.iter()
                .filter(|ev| {
                    ev.get("ph").and_then(|p| p.as_str()) == Some("X")
                })
                .count()
        });
    if spans == 0 {
        bail!("trace contains no spans — nothing was recorded");
    }
    std::fs::write(path, text).with_context(|| format!("write {path}"))?;
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    if !artifacts_available() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let steps = args.get_u64("steps", 200);
    let seed = args.get_u64("seed", 7);
    let rt = DlrmRuntime::load(&artifacts_dir())?;
    println!(
        "DLRM: {} params across {} tensors; batch {}",
        rt.manifest.num_params,
        rt.manifest.params.len(),
        rt.manifest.batch
    );
    let mut params = rt.init_params(seed)?;
    let mut rng = Pcg32::new(seed);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
        let (p, loss) = rt.train_step(params, &batch)?;
        params = p;
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{steps} steps in {dt:.2}s ({:.1} steps/s, {:.0} samples/s)",
        steps as f64 / dt,
        steps as f64 * rt.manifest.batch as f64 / dt
    );
    Ok(())
}
