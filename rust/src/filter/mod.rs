//! Row predicates and their pushdown machinery.
//!
//! The paper's central workload observation is that training jobs "read
//! and *heavily filter* massive and evolving datasets" (§5.1): recency
//! windows for continuous training, negative downsampling, feature
//! checks, and deterministic sampling. Before this module, rust_pallas
//! applied those filters *last* — inside the transform DAG, after
//! Tectonic I/O, decryption, decompression, and full stripe decode had
//! paid for every discarded row. A [`RowPredicate`] instead travels in
//! the session spec and is evaluated at three descending levels:
//!
//! 1. **stripe pruning** — [`RowPredicate::prunes_stripe`] consults the
//!    footer's [`StripeStats`] so provably-empty stripes issue **zero**
//!    I/Os (and the Master never turns fully-filtered files into
//!    splits);
//! 2. **row selection** — partially-matching stripes decode once, and
//!    [`RowPredicate::select_rows`] produces a selection vector
//!    ([`crate::data::ColumnarBatch::selection`]) so transforms and
//!    tensorization touch only surviving rows;
//! 3. **selectivity estimation** — [`RowPredicate::selectivity`] gives
//!    pipeline tuners (InTune-style DPP right-sizing) the expected
//!    surviving fraction before any byte is read.
//!
//! Every decision is a pure function of row *content* (label, event
//! timestamp, feature presence) — never of the row's physical position.
//! That makes filtered sessions dedup-compatible: the old `Sampling`
//! transform op hashed the row index and forced Dedup-encoded reads
//! back onto the duplication-oblivious path; [`RowPredicate::SampleRate`]
//! hashes the timestamp instead and composes with the dedup-aware path.

use crate::data::{Bitmap, ColumnarBatch, Sample};
use crate::dwrf::StripeStats;
use crate::schema::FeatureId;
use crate::transforms::hash64;

/// Prior positive-label rate used when estimating the selectivity of
/// label predicates without data statistics (the generator's CTR).
pub const POSITIVE_RATE_PRIOR: f64 = 0.12;

/// Prior row-coverage of an arbitrary feature (Table 4-ish average),
/// used when estimating feature-presence selectivity without stats.
pub const PRESENCE_PRIOR: f64 = 0.5;

/// A row filter a training session pushes down the read path.
#[derive(Clone, Debug, PartialEq)]
pub enum RowPredicate {
    /// Keep rows with `min <= timestamp <= max` (inclusive) — the
    /// continuous-training recency read.
    TimestampRange { min: u64, max: u64 },
    /// Label-based negative downsampling: keep every positive
    /// (label > 0) row; keep a negative with probability `rate`,
    /// decided deterministically from `(seed, timestamp)`.
    NegativeDownsample { rate: f64, seed: u64 },
    /// Keep rows where the feature is present (non-absent dense value /
    /// non-empty sparse list).
    FeaturePresent { feature: FeatureId },
    /// Deterministic row sampling at `rate`, keyed on
    /// `(seed, timestamp)` — content-addressed, so the decision is
    /// independent of row order and of duplication layout.
    SampleRate { rate: f64, seed: u64 },
    /// Conjunction: a row survives iff every conjunct keeps it.
    And(Vec<RowPredicate>),
}

/// Deterministic keep decision: uniform in [0,1) from a 64-bit mix of
/// the seed and the row's event timestamp.
#[inline]
fn keep(seed: u64, timestamp: u64, rate: f64) -> bool {
    let h = hash64(seed ^ timestamp.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    ((h >> 11) as f64 / (1u64 << 53) as f64) < rate
}

impl RowPredicate {
    /// Conjunction constructor that flattens trivial cases.
    pub fn and(mut preds: Vec<RowPredicate>) -> RowPredicate {
        if preds.len() == 1 {
            preds.pop().unwrap()
        } else {
            RowPredicate::And(preds)
        }
    }

    /// Features the predicate inspects (recursively). Presence can only
    /// be evaluated over *decoded* columns, so these must be part of the
    /// read projection — [`crate::dpp::SessionSpec::with_predicate`]
    /// extends the projection with them automatically.
    pub fn features(&self) -> Vec<FeatureId> {
        fn walk(p: &RowPredicate, out: &mut Vec<FeatureId>) {
            match p {
                RowPredicate::FeaturePresent { feature } => {
                    out.push(*feature)
                }
                RowPredicate::And(ps) => {
                    for q in ps {
                        walk(q, out);
                    }
                }
                _ => {}
            }
        }
        let mut out = Vec::new();
        walk(self, &mut out);
        out.sort();
        out.dedup();
        out
    }

    /// Estimated fraction of rows that survive, without data stats
    /// (documented priors; conjuncts assumed independent).
    pub fn selectivity(&self) -> f64 {
        match self {
            RowPredicate::TimestampRange { min, max } => {
                if min > max {
                    0.0
                } else {
                    1.0 // unknown data range: conservative full estimate
                }
            }
            RowPredicate::NegativeDownsample { rate, .. } => {
                let rate = rate.clamp(0.0, 1.0);
                POSITIVE_RATE_PRIOR + (1.0 - POSITIVE_RATE_PRIOR) * rate
            }
            RowPredicate::FeaturePresent { .. } => PRESENCE_PRIOR,
            RowPredicate::SampleRate { rate, .. } => rate.clamp(0.0, 1.0),
            RowPredicate::And(ps) => ps
                .iter()
                .map(|p| p.selectivity())
                .product::<f64>()
                .clamp(0.0, 1.0),
        }
    }

    /// Stats-aware estimate for one stripe or row group (the
    /// InTune-style signal): refines the priors with the footer
    /// statistics. Degenerate stats (`min_timestamp > max_timestamp` —
    /// the `Default` sentinel a rows-free stripe serializes) mean "no
    /// rows": zero selectivity contribution, handled explicitly rather
    /// than through accidental comparison behavior.
    pub fn stripe_selectivity(&self, stats: &StripeStats, rows: u32) -> f64 {
        if stats.is_empty_domain() || rows == 0 {
            return 0.0;
        }
        match self {
            RowPredicate::TimestampRange { min, max } => {
                if *min > *max
                    || stats.min_timestamp > *max
                    || stats.max_timestamp < *min
                {
                    return 0.0;
                }
                let span = (stats.max_timestamp - stats.min_timestamp) as f64;
                if span == 0.0 {
                    return 1.0;
                }
                let lo = stats.min_timestamp.max(*min);
                let hi = stats.max_timestamp.min(*max);
                ((hi - lo) as f64 / span).clamp(0.0, 1.0)
            }
            RowPredicate::NegativeDownsample { rate, .. } => {
                let rows = rows.max(1) as f64;
                let pos = stats.label_positives as f64 / rows;
                (pos + (1.0 - pos) * rate.clamp(0.0, 1.0)).clamp(0.0, 1.0)
            }
            RowPredicate::FeaturePresent { feature } => {
                if stats.maybe_present(feature.0) {
                    PRESENCE_PRIOR
                } else {
                    0.0
                }
            }
            RowPredicate::SampleRate { rate, .. } => rate.clamp(0.0, 1.0),
            RowPredicate::And(ps) => ps
                .iter()
                .map(|p| p.stripe_selectivity(stats, rows))
                .product::<f64>()
                .clamp(0.0, 1.0),
        }
    }

    /// Row-weighted selectivity estimate across a whole set of stripes —
    /// the feed-forward signal the Master's autoscaler starts from
    /// before a single row has been decoded (online correction from
    /// `filtered_rows / decoded_rows` takes over as observations
    /// arrive). Falls back to the stats-free prior when the set is
    /// empty.
    pub fn dataset_selectivity<'a>(
        &self,
        stripes: impl IntoIterator<Item = (&'a StripeStats, u32)>,
    ) -> f64 {
        let mut rows = 0u64;
        let mut surviving = 0.0f64;
        for (stats, n) in stripes {
            rows += n as u64;
            surviving += self.stripe_selectivity(stats, n) * n as f64;
        }
        if rows == 0 {
            self.selectivity()
        } else {
            (surviving / rows as f64).clamp(0.0, 1.0)
        }
    }

    /// `true` proves that **no** row of a stripe (or row group) with
    /// these statistics can match — the unit (and all its I/Os) is
    /// skippable. One-sided: `false` only means "must decode to decide".
    ///
    /// Degenerate stats (`min_timestamp > max_timestamp`) can only come
    /// from a stats record that observed zero rows — an empty or
    /// fully-deduped stripe serializing `StripeStats::default()` — so
    /// they prune under *every* predicate, explicitly, instead of
    /// depending on how each arm's comparisons happen to fall out.
    pub fn prunes_stripe(&self, stats: &StripeStats, rows: u32) -> bool {
        if stats.is_empty_domain() {
            return true;
        }
        match self {
            RowPredicate::TimestampRange { min, max } => {
                *min > *max
                    || stats.min_timestamp > *max
                    || stats.max_timestamp < *min
            }
            RowPredicate::NegativeDownsample { rate, .. } => {
                // Only provably empty when no positives exist and every
                // negative is dropped.
                stats.label_positives == 0 && *rate <= 0.0
            }
            RowPredicate::FeaturePresent { feature } => {
                !stats.maybe_present(feature.0)
            }
            RowPredicate::SampleRate { rate, .. } => *rate <= 0.0,
            RowPredicate::And(ps) => {
                ps.iter().any(|p| p.prunes_stripe(stats, rows))
            }
        }
    }

    /// Does one row survive? `present` answers feature-presence for this
    /// row (over whatever columns the caller decoded).
    pub fn matches_row(
        &self,
        label: f32,
        timestamp: u64,
        present: &dyn Fn(FeatureId) -> bool,
    ) -> bool {
        match self {
            RowPredicate::TimestampRange { min, max } => {
                (*min..=*max).contains(&timestamp)
            }
            RowPredicate::NegativeDownsample { rate, seed } => {
                label > 0.0 || keep(*seed, timestamp, *rate)
            }
            RowPredicate::FeaturePresent { feature } => present(*feature),
            RowPredicate::SampleRate { rate, seed } => {
                keep(*seed, timestamp, *rate)
            }
            RowPredicate::And(ps) => ps
                .iter()
                .all(|p| p.matches_row(label, timestamp, present)),
        }
    }

    /// Row-level convenience over a row-map [`Sample`] (the non-flatmap
    /// decode path) — agrees bit-for-bit with the columnar evaluation.
    pub fn matches_sample(&self, s: &Sample) -> bool {
        self.matches_row(s.label, s.timestamp, &|f| {
            s.get_dense(f).is_some()
                || s.get_sparse(f).is_some_and(|v| !v.is_empty())
        })
    }

    /// Evaluate over parallel row metadata, with presence answered by
    /// `present(feature, row)`. Returns the surviving-row bitmap.
    pub fn select_rows(
        &self,
        labels: &[f32],
        timestamps: &[u64],
        present: &dyn Fn(FeatureId, usize) -> bool,
    ) -> Bitmap {
        let n = labels.len();
        debug_assert_eq!(n, timestamps.len());
        let mut bm = Bitmap::new(n);
        for r in 0..n {
            if self.matches_row(labels[r], timestamps[r], &|f| present(f, r)) {
                bm.set(r);
            }
        }
        bm
    }

    /// Evaluate over a decoded per-row columnar batch (presence looked
    /// up in the batch's decoded columns).
    pub fn select_batch(&self, batch: &ColumnarBatch) -> Bitmap {
        self.select_rows(&batch.labels, &batch.timestamps, &|f, r| {
            batch_presence(batch, f, r)
        })
    }
}

/// Is feature `f` present on row `row` of the batch? Dense: presence
/// bit; sparse: non-empty id list; undecoded/unknown features: absent.
pub fn batch_presence(batch: &ColumnarBatch, f: FeatureId, row: usize) -> bool {
    if let Some(c) = batch.dense.iter().find(|c| c.id == f) {
        return c.present.get(row);
    }
    if let Some(c) = batch.sparse.iter().find(|c| c.id == f) {
        return !c.row(row).is_empty();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SparseValue;

    fn sample(ts: u64, label: f32, with_feat: bool) -> Sample {
        let mut s = Sample {
            dense: vec![(FeatureId(0), ts as f32)],
            label,
            timestamp: ts,
            ..Default::default()
        };
        if with_feat {
            s.sparse
                .push((FeatureId(10), SparseValue::ids(vec![ts, ts + 1])));
        }
        s.sort_features();
        s
    }

    fn batch(samples: &[Sample]) -> ColumnarBatch {
        ColumnarBatch::from_samples(samples, &[FeatureId(0)], &[FeatureId(10)])
    }

    #[test]
    fn timestamp_range_selects_window() {
        let samples: Vec<Sample> =
            (0..10).map(|i| sample(100 + i, 0.0, true)).collect();
        let p = RowPredicate::TimestampRange { min: 103, max: 106 };
        let sel = p.select_batch(&batch(&samples));
        assert_eq!(sel.ones(), vec![3, 4, 5, 6]);
        // Sample path agrees.
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(p.matches_sample(s), sel.get(i));
        }
    }

    #[test]
    fn negative_downsample_keeps_every_positive() {
        let samples: Vec<Sample> = (0..200)
            .map(|i| sample(i, (i % 5 == 0) as u64 as f32, false))
            .collect();
        let p = RowPredicate::NegativeDownsample {
            rate: 0.25,
            seed: 9,
        };
        let sel = p.select_batch(&batch(&samples));
        let mut kept_pos = 0;
        let mut kept_neg = 0;
        for (i, s) in samples.iter().enumerate() {
            if s.label > 0.0 {
                assert!(sel.get(i), "positive row {i} must survive");
                kept_pos += 1;
            } else if sel.get(i) {
                kept_neg += 1;
            }
        }
        assert_eq!(kept_pos, 40);
        // ~25% of the 160 negatives, with slack.
        assert!((15..=70).contains(&kept_neg), "kept {kept_neg} negatives");
    }

    #[test]
    fn feature_presence_tracks_columns_and_samples() {
        let samples: Vec<Sample> =
            (0..8).map(|i| sample(i, 0.0, i % 2 == 0)).collect();
        let p = RowPredicate::FeaturePresent {
            feature: FeatureId(10),
        };
        let sel = p.select_batch(&batch(&samples));
        assert_eq!(sel.ones(), vec![0, 2, 4, 6]);
        for (i, s) in samples.iter().enumerate() {
            assert_eq!(p.matches_sample(s), sel.get(i));
        }
        // An unknown feature is absent everywhere.
        let q = RowPredicate::FeaturePresent {
            feature: FeatureId(777),
        };
        assert_eq!(q.select_batch(&batch(&samples)).count_ones(), 0);
    }

    #[test]
    fn sample_rate_is_deterministic_and_order_free() {
        let samples: Vec<Sample> =
            (0..500).map(|i| sample(i * 7, 0.0, false)).collect();
        let p = RowPredicate::SampleRate { rate: 0.3, seed: 4 };
        let a = p.select_batch(&batch(&samples));
        let b = p.select_batch(&batch(&samples));
        assert_eq!(a, b);
        let frac = a.count_ones() as f64 / 500.0;
        assert!((frac - 0.3).abs() < 0.08, "{frac}");
        // Decision keys on content (timestamp), not position: reversing
        // the rows keeps the same per-row outcome.
        let mut rev = samples.clone();
        rev.reverse();
        let c = p.select_batch(&batch(&rev));
        for i in 0..500 {
            assert_eq!(a.get(i), c.get(499 - i));
        }
    }

    #[test]
    fn conjunction_intersects() {
        let samples: Vec<Sample> =
            (0..50).map(|i| sample(i, (i % 2) as f32, i < 25)).collect();
        let p = RowPredicate::and(vec![
            RowPredicate::TimestampRange { min: 10, max: 40 },
            RowPredicate::FeaturePresent {
                feature: FeatureId(10),
            },
        ]);
        let sel = p.select_batch(&batch(&samples));
        assert_eq!(sel.ones(), (10u32..25).collect::<Vec<_>>());
        // Single-element and() unwraps.
        assert_eq!(
            RowPredicate::and(vec![RowPredicate::SampleRate {
                rate: 1.0,
                seed: 0
            }]),
            RowPredicate::SampleRate { rate: 1.0, seed: 0 }
        );
    }

    #[test]
    fn features_collects_presence_features_recursively() {
        let p = RowPredicate::And(vec![
            RowPredicate::FeaturePresent {
                feature: FeatureId(9),
            },
            RowPredicate::SampleRate { rate: 0.5, seed: 0 },
            RowPredicate::And(vec![
                RowPredicate::FeaturePresent {
                    feature: FeatureId(3),
                },
                RowPredicate::FeaturePresent {
                    feature: FeatureId(9),
                },
            ]),
        ]);
        assert_eq!(p.features(), vec![FeatureId(3), FeatureId(9)]);
        assert!(RowPredicate::SampleRate { rate: 1.0, seed: 0 }
            .features()
            .is_empty());
    }

    #[test]
    fn dataset_selectivity_is_row_weighted() {
        // Stripe A (32 rows) fully inside the window, stripe B (96 rows)
        // fully outside: the dataset-wide estimate is the row-weighted
        // blend, not the per-stripe average.
        let a: Vec<Sample> =
            (0..32).map(|i| sample(1000 + i, 0.0, true)).collect();
        let b: Vec<Sample> =
            (0..96).map(|i| sample(5000 + i, 0.0, true)).collect();
        let sa = StripeStats::from_samples(&a);
        let sb = StripeStats::from_samples(&b);
        let p = RowPredicate::TimestampRange { min: 0, max: 2000 };
        let est = p.dataset_selectivity([(&sa, 32u32), (&sb, 96u32)]);
        assert!((est - 0.25).abs() < 1e-9, "{est}");
        // Empty stripe set falls back to the stats-free prior.
        let q = RowPredicate::SampleRate { rate: 0.4, seed: 1 };
        let none: [(&StripeStats, u32); 0] = [];
        assert!((q.dataset_selectivity(none) - 0.4).abs() < 1e-9);
    }

    #[test]
    fn stripe_pruning_is_sound_and_effective() {
        let samples: Vec<Sample> =
            (0..32).map(|i| sample(1000 + i, 0.0, true)).collect();
        let stats = StripeStats::from_samples(&samples);
        let rows = samples.len() as u32;

        // Disjoint window prunes; overlapping window does not.
        let gone = RowPredicate::TimestampRange { min: 0, max: 999 };
        assert!(gone.prunes_stripe(&stats, rows));
        let hit = RowPredicate::TimestampRange {
            min: 1010,
            max: 1015,
        };
        assert!(!hit.prunes_stripe(&stats, rows));

        // No positives + rate 0 prunes; any rate > 0 does not.
        assert!(RowPredicate::NegativeDownsample { rate: 0.0, seed: 1 }
            .prunes_stripe(&stats, rows));
        assert!(!RowPredicate::NegativeDownsample { rate: 0.1, seed: 1 }
            .prunes_stripe(&stats, rows));

        // Absent feature prunes; present feature does not.
        assert!(RowPredicate::FeaturePresent {
            feature: FeatureId(55_555)
        }
        .prunes_stripe(&stats, rows));
        assert!(!RowPredicate::FeaturePresent {
            feature: FeatureId(10)
        }
        .prunes_stripe(&stats, rows));

        // A conjunction prunes when any conjunct prunes.
        assert!(RowPredicate::And(vec![hit.clone(), gone.clone()])
            .prunes_stripe(&stats, rows));

        // Soundness: a non-pruned stripe may be empty, but a pruned
        // stripe can never contain a matching row.
        for p in [
            gone,
            RowPredicate::NegativeDownsample { rate: 0.0, seed: 1 },
            RowPredicate::SampleRate { rate: 0.0, seed: 2 },
        ] {
            assert!(samples.iter().all(|s| !p.matches_sample(s)));
        }
    }

    #[test]
    fn selectivity_estimates_are_probabilities() {
        let preds = [
            RowPredicate::TimestampRange { min: 5, max: 1 },
            RowPredicate::TimestampRange { min: 0, max: 100 },
            RowPredicate::NegativeDownsample { rate: 0.5, seed: 0 },
            RowPredicate::FeaturePresent {
                feature: FeatureId(1),
            },
            RowPredicate::SampleRate { rate: 0.1, seed: 0 },
        ];
        for p in &preds {
            let s = p.selectivity();
            assert!((0.0..=1.0).contains(&s), "{p:?} -> {s}");
        }
        assert_eq!(preds[0].selectivity(), 0.0);
        let conj = RowPredicate::And(vec![
            RowPredicate::SampleRate { rate: 0.5, seed: 0 },
            RowPredicate::SampleRate { rate: 0.5, seed: 1 },
        ]);
        assert!((conj.selectivity() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn degenerate_stats_prune_as_no_rows_under_every_predicate() {
        // An empty / fully-deduped stripe serializes
        // `StripeStats::default()`: min_timestamp = u64::MAX >
        // max_timestamp = 0. That must read as "no rows" — pruned by
        // every predicate, zero selectivity contribution — not as
        // whatever each arm's comparisons happen to do.
        let empty = StripeStats::default();
        assert!(empty.is_empty_domain());
        let preds = [
            RowPredicate::TimestampRange { min: 0, max: u64::MAX },
            RowPredicate::NegativeDownsample { rate: 1.0, seed: 0 },
            RowPredicate::SampleRate { rate: 1.0, seed: 0 },
            RowPredicate::FeaturePresent {
                feature: FeatureId(0),
            },
            RowPredicate::And(vec![RowPredicate::SampleRate {
                rate: 1.0,
                seed: 0,
            }]),
        ];
        for p in &preds {
            assert!(
                p.prunes_stripe(&empty, 0),
                "{p:?} must prune degenerate stats"
            );
            assert_eq!(
                p.stripe_selectivity(&empty, 0),
                0.0,
                "{p:?} must contribute zero selectivity"
            );
        }
        // Even with a presence bit set (a half-written record), min > max
        // still proves zero rows.
        let mut weird = StripeStats::default();
        weird.mark_present(3);
        assert!(RowPredicate::FeaturePresent {
            feature: FeatureId(3)
        }
        .prunes_stripe(&weird, 0));
        // And a non-degenerate stripe is unaffected.
        let live = StripeStats {
            min_timestamp: 10,
            max_timestamp: 20,
            label_positives: 1,
            presence: [0; 2],
        };
        assert!(!RowPredicate::SampleRate { rate: 1.0, seed: 0 }
            .prunes_stripe(&live, 8));
    }

    #[test]
    fn degenerate_stats_contribute_zero_to_dataset_selectivity() {
        let samples: Vec<Sample> =
            (0..64).map(|i| sample(1000 + i, 0.0, true)).collect();
        let live = StripeStats::from_samples(&samples);
        let empty = StripeStats::default();
        let p = RowPredicate::TimestampRange { min: 0, max: u64::MAX };
        // The empty stripe advertises rows (a corrupt footer could) but
        // its degenerate stats still contribute nothing: the estimate is
        // diluted by the claimed rows, never inflated by them.
        let est = p.dataset_selectivity([(&live, 64u32), (&empty, 64u32)]);
        assert!((est - 0.5).abs() < 1e-9, "{est}");
        // With zero claimed rows it's invisible.
        let est2 = p.dataset_selectivity([(&live, 64u32), (&empty, 0u32)]);
        assert!((est2 - 1.0).abs() < 1e-9, "{est2}");
    }

    #[test]
    fn stripe_selectivity_refines_with_stats() {
        let samples: Vec<Sample> = (0..100)
            .map(|i| sample(i, (i < 10) as u64 as f32, false))
            .collect();
        let stats = StripeStats::from_samples(&samples);
        // Half-open overlap of the ts span ≈ 0.5.
        let p = RowPredicate::TimestampRange { min: 0, max: 49 };
        let s = p.stripe_selectivity(&stats, 100);
        assert!((s - 0.49).abs() < 0.05, "{s}");
        // Downsample: 10% positives + 50% of negatives ≈ 0.55.
        let d = RowPredicate::NegativeDownsample { rate: 0.5, seed: 0 }
            .stripe_selectivity(&stats, 100);
        assert!((d - 0.55).abs() < 1e-9, "{d}");
        // Absent feature → 0.
        let f = RowPredicate::FeaturePresent {
            feature: FeatureId(424_242),
        }
        .stripe_selectivity(&stats, 100);
        assert_eq!(f, 0.0);
    }
}
