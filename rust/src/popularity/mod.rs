//! Byte/feature popularity across training jobs (§5.2, Fig 7) and the
//! feature-reordering input it feeds (§7.5).
//!
//! Jobs for a model mostly build on the production baseline, so their
//! projections overlap heavily on popular features. Simulating a month
//! of jobs sampling Zipf-weighted projections over a schema yields the
//! byte-popularity CDF of Fig 7; the same counts, windowed over recent
//! jobs, produce the popularity order the DWRF writer uses for FR.

use crate::config::RmConfig;
use crate::schema::{FeatureId, Schema};
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{read_or_recover, write_or_recover, RwLock};
use crate::util::rng::Pcg32;
use crate::util::stats::{bytes_needed_for_io, popularity_cdf};
use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

/// Live per-feature demand accumulator: stored-byte weight and a
/// byte-weighted access counter, both lock-free. Broker serves feed it
/// concurrently; the f64 accumulators live as bit-cast `AtomicU64`s
/// (the sums are advisory popularity signals — Relaxed is enough, the
/// CAS loop just keeps increments from being lost).
#[derive(Default)]
pub struct FeatureDemand {
    /// Stored bytes-per-row weight, as f64 bits.
    weight: AtomicU64,
    /// Byte-weighted access accumulator, as f64 bits.
    accessed: AtomicU64,
}

impl FeatureDemand {
    // Relaxed store/load: `weight` is a last-writer-wins scalar every
    // job rewrites to the same schema-derived value; readers tolerate a
    // stale weight and nothing else is published through it.
    fn set_weight(&self, w: f64) {
        self.weight.store(w.to_bits(), Ordering::Relaxed);
    }

    fn weight(&self) -> f64 {
        f64::from_bits(self.weight.load(Ordering::Relaxed))
    }

    // Relaxed CAS loop: `accessed` is an independent monotone
    // accumulator — the CAS makes each add atomic (no update lost at
    // any ordering), and no cross-variable invariant hangs off it, so
    // no acquire/release edge is needed.
    fn add_accessed(&self, bytes: f64) {
        let mut cur = self.accessed.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + bytes).to_bits();
            match self.accessed.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    // Relaxed load: reporting read of the monotone accumulator above.
    fn accessed(&self) -> f64 {
        f64::from_bits(self.accessed.load(Ordering::Relaxed))
    }
}

/// Accumulated access statistics across jobs — and, since the broker's
/// column cache went popularity-aware, the *live* per-feature demand
/// tracker its admission/eviction order reads. All recording paths take
/// `&self`: per-feature counters are atomics, and the feature map is
/// behind an `RwLock` whose write path only runs the first time a
/// feature is seen, so concurrent broker serves never contend on a
/// global lock in steady state.
#[derive(Default)]
pub struct AccessStats {
    per_feature: RwLock<HashMap<FeatureId, Arc<FeatureDemand>>>,
    jobs: AtomicU64,
}

impl Clone for AccessStats {
    /// Snapshot clone: the copy starts from this tracker's current
    /// counter values and accumulates independently afterwards.
    //
    // Relaxed loads: each cell is copied independently; a clone taken
    // concurrently with recording sees a torn-but-valid snapshot (some
    // of the in-flight adds, none corrupted), which is all a snapshot
    // of monotone statistics can promise.
    fn clone(&self) -> AccessStats {
        let map = read_or_recover(&self.per_feature, "popularity");
        AccessStats {
            per_feature: RwLock::new(
                map.iter()
                    .map(|(k, v)| {
                        (
                            *k,
                            Arc::new(FeatureDemand {
                                weight: AtomicU64::new(
                                    v.weight.load(Ordering::Relaxed),
                                ),
                                accessed: AtomicU64::new(
                                    v.accessed.load(Ordering::Relaxed),
                                ),
                            }),
                        )
                    })
                    .collect(),
            ),
            // Relaxed: same snapshot contract as the per-cell loads above.
            jobs: AtomicU64::new(self.jobs.load(Ordering::Relaxed)),
        }
    }
}

impl fmt::Debug for AccessStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AccessStats")
            .field(
                "features",
                &read_or_recover(&self.per_feature, "popularity").len(),
            )
            .field("jobs", &self.jobs())
            .finish()
    }
}

impl AccessStats {
    /// Jobs recorded so far.
    //
    // Relaxed: `jobs` is a monotone counter read for reporting and
    // demand normalization; a slightly stale count is fine and no other
    // state is synchronized through it.
    pub fn jobs(&self) -> u64 {
        self.jobs.load(Ordering::Relaxed)
    }

    /// The demand accumulator for one feature (created on first touch).
    fn entry(&self, id: FeatureId) -> Arc<FeatureDemand> {
        if let Some(d) =
            read_or_recover(&self.per_feature, "popularity").get(&id)
        {
            return d.clone();
        }
        write_or_recover(&self.per_feature, "popularity")
            .entry(id)
            .or_default()
            .clone()
    }

    /// Record one job's projection over the schema.
    //
    // Relaxed fetch_add: the job counter is an independent monotone
    // cell (atomic RMW loses nothing at any ordering); the per-feature
    // updates below have their own invariant comments.
    pub fn record_job(&self, schema: &Schema, projection: &[FeatureId]) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        for f in &schema.features {
            let d = self.entry(f.id);
            d.set_weight(f.expected_bytes_per_row());
            if projection.contains(&f.id) {
                d.add_accessed(f.expected_bytes_per_row());
            }
        }
    }

    /// Record one broker column serve: `bytes` of feature `id` were
    /// demanded by some session. This is the live feed the column
    /// cache's admission/eviction order runs on.
    pub fn record_serve(&self, id: FeatureId, bytes: u64) {
        self.entry(id).add_accessed(bytes as f64);
    }

    /// Live demand score for one feature: accumulated byte-weighted
    /// accesses (0.0 for never-seen features).
    pub fn demand(&self, id: FeatureId) -> f64 {
        read_or_recover(&self.per_feature, "popularity")
            .get(&id)
            .map_or(0.0, |d| d.accessed())
    }

    /// Consistent point-in-time view of every feature's
    /// (weight, accessed) pair.
    fn snapshot(&self) -> Vec<(FeatureId, (f64, f64))> {
        read_or_recover(&self.per_feature, "popularity")
            .iter()
            .map(|(k, v)| (*k, (v.weight(), v.accessed())))
            .collect()
    }

    /// Fig 7's CDF: (fraction of stored bytes, fraction of I/O served).
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let items: Vec<(f64, f64)> =
            self.snapshot().into_iter().map(|(_, wa)| wa).collect();
        popularity_cdf(&items)
    }

    /// % of bytes required to absorb `io_frac` of I/O.
    pub fn bytes_for_io(&self, io_frac: f64) -> f64 {
        bytes_needed_for_io(&self.cdf(), io_frac)
    }

    /// Popularity-ordered feature list (most accessed first) — the FR
    /// writer order (§7.5: ordered by popularity in jobs launched within
    /// a recent window).
    pub fn reorder(&self) -> Vec<FeatureId> {
        let mut feats = self.snapshot();
        // Rank by access density (accesses per stored byte): the features
        // most often read per byte of footprint lead each stripe, which
        // both concentrates job projections at the stripe front (FR) and
        // is the natural SSD-tiering order (§7.2).
        feats.sort_by(|a, b| {
            let da = a.1 .1 / a.1 .0.max(1e-12);
            let db = b.1 .1 / b.1 .0.max(1e-12);
            db.partial_cmp(&da).unwrap().then(a.0.cmp(&b.0))
        });
        feats.into_iter().map(|(id, _)| id).collect()
    }
}

/// Simulate a month of training jobs for an RM over a schema; returns
/// the accumulated access stats.
pub fn simulate_month(
    rng: &mut Pcg32,
    rm: &RmConfig,
    schema: &Schema,
    jobs: usize,
) -> AccessStats {
    let stats = AccessStats::default();
    let take = (schema.features.len() as f64 * rm.frac_feats_used())
        .round()
        .max(1.0) as usize;
    for _ in 0..jobs {
        let proj = schema.sample_projection(rng, take, rm.popularity_zipf_s);
        stats.record_job(schema, &proj);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;

    fn setup(id: RmId) -> (Pcg32, RmConfig, Schema) {
        let mut rng = Pcg32::new(31);
        let rm = RmConfig::get(id);
        let schema = Schema::synthetic(
            &mut rng,
            200,
            60,
            rm.avg_coverage,
            rm.avg_sparse_len,
        );
        (rng, rm, schema)
    }

    #[test]
    fn popular_bytes_absorb_most_io() {
        let (mut rng, rm, schema) = setup(RmId::Rm1);
        let stats = simulate_month(&mut rng, &rm, &schema, 120);
        let frac = stats.bytes_for_io(0.8);
        // Paper Fig 7: 39% of RM1 bytes serve 80% of I/O. Assert the
        // qualitative shape (well under uniform = 80%).
        assert!(frac < 0.6, "RM1 bytes-for-80%-io = {frac}");
        assert!(frac > 0.05);
    }

    #[test]
    fn rm3_is_more_concentrated_than_rm1() {
        // Paper: RM3 needs only 18% of bytes vs RM1's 39%.
        let (mut rng1, rm1, schema1) = setup(RmId::Rm1);
        let s1 = simulate_month(&mut rng1, &rm1, &schema1, 120);
        let (mut rng3, rm3, schema3) = setup(RmId::Rm3);
        let s3 = simulate_month(&mut rng3, &rm3, &schema3, 120);
        assert!(
            s3.bytes_for_io(0.8) < s1.bytes_for_io(0.8),
            "RM3 {} !< RM1 {}",
            s3.bytes_for_io(0.8),
            s1.bytes_for_io(0.8)
        );
    }

    #[test]
    fn reorder_puts_projected_features_first() {
        let (mut rng, rm, schema) = setup(RmId::Rm2);
        let stats = simulate_month(&mut rng, &rm, &schema, 60);
        let order = stats.reorder();
        assert_eq!(order.len(), schema.features.len());
        // Front of the order must be dominated by low-popularity-rank
        // (popular) features.
        let front_ranks: Vec<usize> = order[..20]
            .iter()
            .map(|id| schema.by_id(*id).unwrap().popularity_rank)
            .collect();
        let avg_front: f64 =
            front_ranks.iter().sum::<usize>() as f64 / front_ranks.len() as f64;
        assert!(
            avg_front < schema.features.len() as f64 / 3.0,
            "front avg rank {avg_front}"
        );
    }

    #[test]
    fn concurrent_serves_lose_no_demand() {
        let stats = Arc::new(AccessStats::default());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let stats = stats.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        stats.record_serve(FeatureId((i % 7) as u32), 10);
                        let _ = stats.demand(FeatureId(t));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let total: f64 =
            (0..7).map(|i| stats.demand(FeatureId(i))).sum();
        assert!((total - 4.0 * 500.0 * 10.0).abs() < 1e-6);
        // A clone snapshots and then diverges.
        let snap = stats.clone();
        stats.record_serve(FeatureId(0), 10);
        assert!(stats.demand(FeatureId(0)) > snap.demand(FeatureId(0)));
    }

    #[test]
    fn cdf_is_monotone_and_complete() {
        let (mut rng, rm, schema) = setup(RmId::Rm2);
        let stats = simulate_month(&mut rng, &rm, &schema, 40);
        let cdf = stats.cdf();
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
        let last = cdf.last().unwrap();
        assert!((last.0 - 1.0).abs() < 1e-9);
        assert!((last.1 - 1.0).abs() < 1e-9);
    }
}
