//! Byte/feature popularity across training jobs (§5.2, Fig 7) and the
//! feature-reordering input it feeds (§7.5).
//!
//! Jobs for a model mostly build on the production baseline, so their
//! projections overlap heavily on popular features. Simulating a month
//! of jobs sampling Zipf-weighted projections over a schema yields the
//! byte-popularity CDF of Fig 7; the same counts, windowed over recent
//! jobs, produce the popularity order the DWRF writer uses for FR.

use crate::config::RmConfig;
use crate::schema::{FeatureId, Schema};
use crate::util::rng::Pcg32;
use crate::util::stats::{bytes_needed_for_io, popularity_cdf};
use std::collections::HashMap;

/// Accumulated access statistics across jobs.
#[derive(Clone, Debug, Default)]
pub struct AccessStats {
    /// feature → (stored bytes weight, access count weighted by bytes).
    pub per_feature: HashMap<FeatureId, (f64, f64)>,
    pub jobs: usize,
}

impl AccessStats {
    /// Record one job's projection over the schema.
    pub fn record_job(&mut self, schema: &Schema, projection: &[FeatureId]) {
        self.jobs += 1;
        for f in &schema.features {
            let entry = self
                .per_feature
                .entry(f.id)
                .or_insert((f.expected_bytes_per_row(), 0.0));
            entry.0 = f.expected_bytes_per_row();
            if projection.contains(&f.id) {
                entry.1 += f.expected_bytes_per_row();
            }
        }
    }

    /// Fig 7's CDF: (fraction of stored bytes, fraction of I/O served).
    pub fn cdf(&self) -> Vec<(f64, f64)> {
        let items: Vec<(f64, f64)> =
            self.per_feature.values().copied().collect();
        popularity_cdf(&items)
    }

    /// % of bytes required to absorb `io_frac` of I/O.
    pub fn bytes_for_io(&self, io_frac: f64) -> f64 {
        bytes_needed_for_io(&self.cdf(), io_frac)
    }

    /// Popularity-ordered feature list (most accessed first) — the FR
    /// writer order (§7.5: ordered by popularity in jobs launched within
    /// a recent window).
    pub fn reorder(&self) -> Vec<FeatureId> {
        let mut feats: Vec<(&FeatureId, &(f64, f64))> =
            self.per_feature.iter().collect();
        // Rank by access density (accesses per stored byte): the features
        // most often read per byte of footprint lead each stripe, which
        // both concentrates job projections at the stripe front (FR) and
        // is the natural SSD-tiering order (§7.2).
        feats.sort_by(|a, b| {
            let da = a.1 .1 / a.1 .0.max(1e-12);
            let db = b.1 .1 / b.1 .0.max(1e-12);
            db.partial_cmp(&da).unwrap().then(a.0.cmp(b.0))
        });
        feats.into_iter().map(|(id, _)| *id).collect()
    }
}

/// Simulate a month of training jobs for an RM over a schema; returns
/// the accumulated access stats.
pub fn simulate_month(
    rng: &mut Pcg32,
    rm: &RmConfig,
    schema: &Schema,
    jobs: usize,
) -> AccessStats {
    let mut stats = AccessStats::default();
    let take = (schema.features.len() as f64 * rm.frac_feats_used())
        .round()
        .max(1.0) as usize;
    for _ in 0..jobs {
        let proj = schema.sample_projection(rng, take, rm.popularity_zipf_s);
        stats.record_job(schema, &proj);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;

    fn setup(id: RmId) -> (Pcg32, RmConfig, Schema) {
        let mut rng = Pcg32::new(31);
        let rm = RmConfig::get(id);
        let schema = Schema::synthetic(
            &mut rng,
            200,
            60,
            rm.avg_coverage,
            rm.avg_sparse_len,
        );
        (rng, rm, schema)
    }

    #[test]
    fn popular_bytes_absorb_most_io() {
        let (mut rng, rm, schema) = setup(RmId::Rm1);
        let stats = simulate_month(&mut rng, &rm, &schema, 120);
        let frac = stats.bytes_for_io(0.8);
        // Paper Fig 7: 39% of RM1 bytes serve 80% of I/O. Assert the
        // qualitative shape (well under uniform = 80%).
        assert!(frac < 0.6, "RM1 bytes-for-80%-io = {frac}");
        assert!(frac > 0.05);
    }

    #[test]
    fn rm3_is_more_concentrated_than_rm1() {
        // Paper: RM3 needs only 18% of bytes vs RM1's 39%.
        let (mut rng1, rm1, schema1) = setup(RmId::Rm1);
        let s1 = simulate_month(&mut rng1, &rm1, &schema1, 120);
        let (mut rng3, rm3, schema3) = setup(RmId::Rm3);
        let s3 = simulate_month(&mut rng3, &rm3, &schema3, 120);
        assert!(
            s3.bytes_for_io(0.8) < s1.bytes_for_io(0.8),
            "RM3 {} !< RM1 {}",
            s3.bytes_for_io(0.8),
            s1.bytes_for_io(0.8)
        );
    }

    #[test]
    fn reorder_puts_projected_features_first() {
        let (mut rng, rm, schema) = setup(RmId::Rm2);
        let stats = simulate_month(&mut rng, &rm, &schema, 60);
        let order = stats.reorder();
        assert_eq!(order.len(), schema.features.len());
        // Front of the order must be dominated by low-popularity-rank
        // (popular) features.
        let front_ranks: Vec<usize> = order[..20]
            .iter()
            .map(|id| schema.by_id(*id).unwrap().popularity_rank)
            .collect();
        let avg_front: f64 =
            front_ranks.iter().sum::<usize>() as f64 / front_ranks.len() as f64;
        assert!(
            avg_front < schema.features.len() as f64 / 3.0,
            "front avg rank {avg_front}"
        );
    }

    #[test]
    fn cdf_is_monotone_and_complete() {
        let (mut rng, rm, schema) = setup(RmId::Rm2);
        let stats = simulate_month(&mut rng, &rm, &schema, 40);
        let cdf = stats.cdf();
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[1].0 >= w[0].0);
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
        let last = cdf.last().unwrap();
        assert!((last.0 - 1.0).abs() < 1e-9);
        assert!((last.1 - 1.0).abs() < 1e-9);
    }
}
