//! Online preprocessing transformations (paper Table 11) and their
//! per-feature DAGs (§6.4, §7.2).
//!
//! Ops fall into the paper's three classes, with very different cost
//! profiles (§6.4: dense norm ≈5%, sparse norm ≈20%, feature generation
//! ≈75% of transform cycles):
//!
//! * **dense normalization** — `Logit`, `BoxCox`, `Onehot`, `Clamp`,
//!   `GetLocalHour`
//! * **sparse normalization** — `SigridHash`, `FirstX`, `PositiveModulus`,
//!   `Enumerate`, `ComputeScore`, `Sampling`
//! * **feature generation** — `Bucketize`, `NGram`, `MapId`, `Cartesian`,
//!   `IdListTransform`
//!
//! All ops are batch-columnar: they consume/produce whole [`Value`]
//! columns (one entry per mini-batch row), matching the paper's
//! "transformations are localized to each mini-batch".

pub mod dag;

pub use dag::{DagStats, Node, TransformDag};

use std::collections::HashMap;
use thiserror::Error;

/// A batch column flowing through a transform DAG.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// One float per row.
    Dense(Vec<f32>),
    /// CSR id lists (optionally scored), one list per row.
    Sparse {
        offsets: Vec<u32>,
        ids: Vec<u64>,
        scores: Option<Vec<f32>>,
    },
}

impl Value {
    pub fn rows(&self) -> usize {
        match self {
            Value::Dense(v) => v.len(),
            Value::Sparse { offsets, .. } => offsets.len() - 1,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            Value::Dense(v) => v.len(),
            Value::Sparse { ids, .. } => ids.len(),
        }
    }

    pub fn sparse_row(&self, r: usize) -> &[u64] {
        match self {
            Value::Sparse { offsets, ids, .. } => {
                &ids[offsets[r] as usize..offsets[r + 1] as usize]
            }
            _ => panic!("sparse_row on dense value"),
        }
    }

    pub fn empty_sparse(rows: usize) -> Value {
        Value::Sparse {
            offsets: vec![0; rows + 1],
            ids: Vec::new(),
            scores: None,
        }
    }
}

#[derive(Error, Debug)]
pub enum XformError {
    #[error("op {op} expects {want} input(s), got {got}")]
    Arity {
        op: &'static str,
        want: usize,
        got: usize,
    },
    #[error("op {op} expects {want} input, got {got}")]
    Type {
        op: &'static str,
        want: &'static str,
        got: &'static str,
    },
    #[error("row count mismatch: {0} vs {1}")]
    Rows(usize, usize),
}

fn type_name(v: &Value) -> &'static str {
    match v {
        Value::Dense(_) => "dense",
        Value::Sparse { .. } => "sparse",
    }
}

/// Cost class (paper §6.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    DenseNorm,
    SparseNorm,
    FeatureGen,
}

/// The 16 production transform ops of Table 11.
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Cartesian product between two sparse features.
    Cartesian,
    /// Shard a dense feature into bucket ids via sorted borders.
    Bucketize { borders: Vec<f32> },
    /// Arithmetic on sparse feature scores: `score * mul + add`.
    ComputeScore { mul: f32, add: f32 },
    /// Replace each id with its position in the list.
    Enumerate,
    /// Positive modulus on sparse ids.
    PositiveModulus { modulus: u64 },
    /// Intersection of two sparse id lists.
    IdListTransform,
    /// Box–Cox normalization of a dense feature.
    BoxCox { lambda: f32 },
    /// Logit normalization of a dense feature.
    Logit { eps: f32 },
    /// Map ids to fixed values (unknown ids → `default`).
    MapId {
        mapping: HashMap<u64, u64>,
        default: u64,
    },
    /// Truncate each id list to the first `x` entries.
    FirstX { x: usize },
    /// Local hour from a POSIX-seconds dense feature.
    GetLocalHour { tz_offset_secs: i64 },
    /// Hash-normalize a sparse id list into `[0, modulus)`.
    SigridHash { salt: u64, modulus: u64 },
    /// N-gram over one sparse feature's list.
    NGram { n: usize },
    /// One-hot-style bucketing of a dense feature into `buckets` ids.
    Onehot { buckets: u32 },
    /// std::clamp on a dense feature.
    Clamp { lo: f32, hi: f32 },
    /// Random row sampling: zero out rows pseudorandomly below `rate`.
    ///
    /// Legacy: the keep-mask hashes the *row position*, which makes the
    /// DAG row-index-sensitive and forces Dedup-encoded reads onto the
    /// oblivious path. New sessions should push sampling down as
    /// [`crate::filter::RowPredicate::SampleRate`], whose decision is
    /// content-keyed and also prunes stripes/bytes before decode.
    Sampling { rate: f32, seed: u64 },
}

/// A cheap, statistically-good 64-bit mix (xorshift-multiply; the
/// production SigridHash is farmhash-family — same role).
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^ (x >> 33)
}

impl Op {
    pub fn name(&self) -> &'static str {
        match self {
            Op::Cartesian => "Cartesian",
            Op::Bucketize { .. } => "Bucketize",
            Op::ComputeScore { .. } => "ComputeScore",
            Op::Enumerate => "Enumerate",
            Op::PositiveModulus { .. } => "PositiveModulus",
            Op::IdListTransform => "IdListTransform",
            Op::BoxCox { .. } => "BoxCox",
            Op::Logit { .. } => "Logit",
            Op::MapId { .. } => "MapId",
            Op::FirstX { .. } => "FirstX",
            Op::GetLocalHour { .. } => "GetLocalHour",
            Op::SigridHash { .. } => "SigridHash",
            Op::NGram { .. } => "NGram",
            Op::Onehot { .. } => "Onehot",
            Op::Clamp { .. } => "Clamp",
            Op::Sampling { .. } => "Sampling",
        }
    }

    pub fn class(&self) -> OpClass {
        match self {
            Op::Logit { .. }
            | Op::BoxCox { .. }
            | Op::Onehot { .. }
            | Op::Clamp { .. }
            | Op::GetLocalHour { .. } => OpClass::DenseNorm,
            Op::SigridHash { .. }
            | Op::FirstX { .. }
            | Op::PositiveModulus { .. }
            | Op::Enumerate
            | Op::ComputeScore { .. }
            | Op::Sampling { .. } => OpClass::SparseNorm,
            Op::Bucketize { .. }
            | Op::NGram { .. }
            | Op::MapId { .. }
            | Op::Cartesian
            | Op::IdListTransform => OpClass::FeatureGen,
        }
    }

    pub fn arity(&self) -> usize {
        match self {
            Op::Cartesian | Op::IdListTransform => 2,
            _ => 1,
        }
    }

    /// Paper §7.2: observed GPU/CPU speedup (V100 vs 20 CPU threads) for
    /// ops where the paper reports one; estimates (same method) otherwise.
    pub fn gpu_speedup(&self) -> f64 {
        match self {
            Op::SigridHash { .. } => 11.9,
            Op::Bucketize { .. } => 1.3,
            Op::NGram { .. } => 6.0,
            Op::Cartesian => 8.0,
            Op::MapId { .. } => 0.8, // hash-table gather: poor on GPU
            Op::ComputeScore { .. } => 9.0,
            Op::Logit { .. } | Op::BoxCox { .. } | Op::Clamp { .. } => 4.0,
            _ => 2.0,
        }
    }

    fn dense_input<'a>(&self, v: &'a Value) -> Result<&'a Vec<f32>, XformError> {
        match v {
            Value::Dense(d) => Ok(d),
            other => Err(XformError::Type {
                op: self.name(),
                want: "dense",
                got: type_name(other),
            }),
        }
    }

    fn sparse_input<'a>(
        &self,
        v: &'a Value,
    ) -> Result<(&'a Vec<u32>, &'a Vec<u64>, Option<&'a Vec<f32>>), XformError> {
        match v {
            Value::Sparse {
                offsets,
                ids,
                scores,
            } => Ok((offsets, ids, scores.as_ref())),
            other => Err(XformError::Type {
                op: self.name(),
                want: "sparse",
                got: type_name(other),
            }),
        }
    }

    /// Apply the op to its inputs, producing a new column.
    pub fn apply(&self, inputs: &[&Value]) -> Result<Value, XformError> {
        if inputs.len() != self.arity() {
            return Err(XformError::Arity {
                op: self.name(),
                want: self.arity(),
                got: inputs.len(),
            });
        }
        match self {
            Op::Clamp { lo, hi } => {
                let d = self.dense_input(inputs[0])?;
                Ok(Value::Dense(d.iter().map(|x| x.clamp(*lo, *hi)).collect()))
            }
            Op::Logit { eps } => {
                let d = self.dense_input(inputs[0])?;
                Ok(Value::Dense(
                    d.iter()
                        .map(|x| {
                            let p = x.clamp(*eps, 1.0 - *eps);
                            (p / (1.0 - p)).ln()
                        })
                        .collect(),
                ))
            }
            Op::BoxCox { lambda } => {
                let d = self.dense_input(inputs[0])?;
                let l = *lambda;
                Ok(Value::Dense(
                    d.iter()
                        .map(|x| {
                            let x = x.max(1e-6);
                            if l.abs() < 1e-6 {
                                x.ln()
                            } else {
                                (x.powf(l) - 1.0) / l
                            }
                        })
                        .collect(),
                ))
            }
            Op::GetLocalHour { tz_offset_secs } => {
                let d = self.dense_input(inputs[0])?;
                Ok(Value::Dense(
                    d.iter()
                        .map(|&t| {
                            let local = t as i64 + tz_offset_secs;
                            (local.rem_euclid(86_400) / 3600) as f32
                        })
                        .collect(),
                ))
            }
            Op::Onehot { buckets } => {
                let d = self.dense_input(inputs[0])?;
                let rows = d.len();
                let mut offsets = Vec::with_capacity(rows + 1);
                offsets.push(0u32);
                let mut ids = Vec::with_capacity(rows);
                for (i, &x) in d.iter().enumerate() {
                    // Hash the float's bucket; stable for equal values.
                    let b = ((x.abs() * 37.0) as u64
                        ^ hash64(x.to_bits() as u64))
                        % *buckets as u64;
                    ids.push(b);
                    offsets.push((i + 1) as u32);
                }
                Ok(Value::Sparse {
                    offsets,
                    ids,
                    scores: None,
                })
            }
            Op::Bucketize { borders } => {
                let d = self.dense_input(inputs[0])?;
                let rows = d.len();
                let mut offsets = Vec::with_capacity(rows + 1);
                offsets.push(0u32);
                let mut ids = Vec::with_capacity(rows);
                for (i, &x) in d.iter().enumerate() {
                    let b = borders.partition_point(|&bd| bd <= x) as u64;
                    ids.push(b);
                    offsets.push((i + 1) as u32);
                }
                Ok(Value::Sparse {
                    offsets,
                    ids,
                    scores: None,
                })
            }
            Op::SigridHash { salt, modulus } => {
                let (offsets, ids, scores) = self.sparse_input(inputs[0])?;
                Ok(Value::Sparse {
                    offsets: offsets.clone(),
                    ids: ids
                        .iter()
                        .map(|&id| hash64(id ^ salt) % modulus)
                        .collect(),
                    scores: scores.cloned(),
                })
            }
            Op::PositiveModulus { modulus } => {
                let (offsets, ids, scores) = self.sparse_input(inputs[0])?;
                Ok(Value::Sparse {
                    offsets: offsets.clone(),
                    ids: ids.iter().map(|&id| id % modulus).collect(),
                    scores: scores.cloned(),
                })
            }
            Op::FirstX { x } => {
                let (offsets, ids, scores) = self.sparse_input(inputs[0])?;
                let rows = offsets.len() - 1;
                let mut new_offsets = Vec::with_capacity(rows + 1);
                new_offsets.push(0u32);
                let mut new_ids = Vec::new();
                let mut new_scores = scores.map(|_| Vec::new());
                for r in 0..rows {
                    let (s, e) = (offsets[r] as usize, offsets[r + 1] as usize);
                    let take = (e - s).min(*x);
                    new_ids.extend_from_slice(&ids[s..s + take]);
                    if let (Some(ns), Some(sc)) = (&mut new_scores, scores) {
                        ns.extend_from_slice(&sc[s..s + take]);
                    }
                    new_offsets.push(new_ids.len() as u32);
                }
                Ok(Value::Sparse {
                    offsets: new_offsets,
                    ids: new_ids,
                    scores: new_scores,
                })
            }
            Op::Enumerate => {
                let (offsets, ids, _) = self.sparse_input(inputs[0])?;
                let rows = offsets.len() - 1;
                let mut new_ids = Vec::with_capacity(ids.len());
                for r in 0..rows {
                    for (i, _) in ids[offsets[r] as usize..offsets[r + 1] as usize]
                        .iter()
                        .enumerate()
                    {
                        new_ids.push(i as u64);
                    }
                }
                Ok(Value::Sparse {
                    offsets: offsets.clone(),
                    ids: new_ids,
                    scores: None,
                })
            }
            Op::ComputeScore { mul, add } => {
                let (offsets, ids, scores) = self.sparse_input(inputs[0])?;
                let scores = match scores {
                    Some(s) => s.iter().map(|x| x * mul + add).collect(),
                    // Scoreless lists: synthesize scores from ids.
                    None => ids
                        .iter()
                        .map(|&id| (id % 1000) as f32 / 1000.0 * mul + add)
                        .collect(),
                };
                Ok(Value::Sparse {
                    offsets: offsets.clone(),
                    ids: ids.clone(),
                    scores: Some(scores),
                })
            }
            Op::MapId { mapping, default } => {
                let (offsets, ids, scores) = self.sparse_input(inputs[0])?;
                Ok(Value::Sparse {
                    offsets: offsets.clone(),
                    ids: ids
                        .iter()
                        .map(|id| *mapping.get(id).unwrap_or(default))
                        .collect(),
                    scores: scores.cloned(),
                })
            }
            Op::NGram { n } => {
                let (offsets, ids, _) = self.sparse_input(inputs[0])?;
                let rows = offsets.len() - 1;
                let n = (*n).max(1);
                let mut new_offsets = Vec::with_capacity(rows + 1);
                new_offsets.push(0u32);
                let mut new_ids = Vec::new();
                for r in 0..rows {
                    let row = &ids[offsets[r] as usize..offsets[r + 1] as usize];
                    if row.len() >= n {
                        for w in row.windows(n) {
                            let mut h = 0xcbf29ce484222325u64;
                            for &id in w {
                                h = hash64(h ^ id);
                            }
                            new_ids.push(h);
                        }
                    }
                    new_offsets.push(new_ids.len() as u32);
                }
                Ok(Value::Sparse {
                    offsets: new_offsets,
                    ids: new_ids,
                    scores: None,
                })
            }
            Op::Cartesian => {
                let (ao, ai, _) = self.sparse_input(inputs[0])?;
                let (bo, bi, _) = self.sparse_input(inputs[1])?;
                let rows = ao.len() - 1;
                if bo.len() - 1 != rows {
                    return Err(XformError::Rows(rows, bo.len() - 1));
                }
                let mut offsets = Vec::with_capacity(rows + 1);
                offsets.push(0u32);
                let mut ids = Vec::new();
                for r in 0..rows {
                    let ra = &ai[ao[r] as usize..ao[r + 1] as usize];
                    let rb = &bi[bo[r] as usize..bo[r + 1] as usize];
                    // Cap the product per row to bound worst-case blowup
                    // (production caps list lengths similarly via FirstX).
                    for &x in ra.iter().take(32) {
                        for &y in rb.iter().take(32) {
                            ids.push(hash64(x.rotate_left(17) ^ y));
                        }
                    }
                    offsets.push(ids.len() as u32);
                }
                Ok(Value::Sparse {
                    offsets,
                    ids,
                    scores: None,
                })
            }
            Op::IdListTransform => {
                let (ao, ai, _) = self.sparse_input(inputs[0])?;
                let (bo, bi, _) = self.sparse_input(inputs[1])?;
                let rows = ao.len() - 1;
                if bo.len() - 1 != rows {
                    return Err(XformError::Rows(rows, bo.len() - 1));
                }
                let mut offsets = Vec::with_capacity(rows + 1);
                offsets.push(0u32);
                let mut ids = Vec::new();
                for r in 0..rows {
                    let ra = &ai[ao[r] as usize..ao[r + 1] as usize];
                    let rb = &bi[bo[r] as usize..bo[r + 1] as usize];
                    // Intersection: sort-merge on small copies.
                    let mut a: Vec<u64> = ra.to_vec();
                    let mut b: Vec<u64> = rb.to_vec();
                    a.sort_unstable();
                    b.sort_unstable();
                    let (mut i, mut j) = (0, 0);
                    while i < a.len() && j < b.len() {
                        match a[i].cmp(&b[j]) {
                            std::cmp::Ordering::Less => i += 1,
                            std::cmp::Ordering::Greater => j += 1,
                            std::cmp::Ordering::Equal => {
                                if ids.last() != Some(&a[i]) {
                                    ids.push(a[i]);
                                }
                                i += 1;
                                j += 1;
                            }
                        }
                    }
                    offsets.push(ids.len() as u32);
                }
                Ok(Value::Sparse {
                    offsets,
                    ids,
                    scores: None,
                })
            }
            Op::Sampling { rate, seed } => {
                // Row-level sampling: emit a dense 0/1 keep-mask derived
                // from (seed, row). Downstream batching drops masked rows.
                let rows = inputs[0].rows();
                let mask: Vec<f32> = (0..rows)
                    .map(|r| {
                        let h = hash64(seed ^ (r as u64).wrapping_mul(0x9E3779B9));
                        if (h as f64 / u64::MAX as f64) < *rate as f64 {
                            1.0
                        } else {
                            0.0
                        }
                    })
                    .collect();
                Ok(Value::Dense(mask))
            }
        }
    }
}

/// All op names, for Table 11 reporting.
pub fn all_op_names() -> Vec<&'static str> {
    vec![
        "Cartesian",
        "Bucketize",
        "ComputeScore",
        "Enumerate",
        "PositiveModulus",
        "IdListTransform",
        "BoxCox",
        "Logit",
        "MapId",
        "FirstX",
        "GetLocalHour",
        "SigridHash",
        "NGram",
        "Onehot",
        "Clamp",
        "Sampling",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse(rows: Vec<Vec<u64>>) -> Value {
        let mut offsets = vec![0u32];
        let mut ids = Vec::new();
        for r in rows {
            ids.extend(r);
            offsets.push(ids.len() as u32);
        }
        Value::Sparse {
            offsets,
            ids,
            scores: None,
        }
    }

    #[test]
    fn clamp_and_logit() {
        let v = Value::Dense(vec![-1.0, 0.5, 2.0]);
        let c = Op::Clamp { lo: 0.0, hi: 1.0 }.apply(&[&v]).unwrap();
        assert_eq!(c, Value::Dense(vec![0.0, 0.5, 1.0]));
        let l = Op::Logit { eps: 1e-4 }.apply(&[&c]).unwrap();
        if let Value::Dense(d) = l {
            assert!(d[0] < -8.0); // logit(eps) very negative
            assert!(d[1].abs() < 1e-6); // logit(0.5) = 0
            assert!(d[2] > 8.0);
        } else {
            panic!()
        }
    }

    #[test]
    fn boxcox_lambda_zero_is_log() {
        let v = Value::Dense(vec![1.0, std::f32::consts::E]);
        let out = Op::BoxCox { lambda: 0.0 }.apply(&[&v]).unwrap();
        if let Value::Dense(d) = out {
            assert!(d[0].abs() < 1e-6);
            assert!((d[1] - 1.0).abs() < 1e-5);
        } else {
            panic!()
        }
    }

    #[test]
    fn get_local_hour() {
        // 2022-01-01 00:30:00 UTC = 1640995800.
        let v = Value::Dense(vec![1_640_995_800.0]);
        let out = Op::GetLocalHour { tz_offset_secs: 0 }.apply(&[&v]).unwrap();
        assert_eq!(out, Value::Dense(vec![0.0]));
        let out = Op::GetLocalHour {
            tz_offset_secs: -8 * 3600,
        }
        .apply(&[&v])
        .unwrap();
        assert_eq!(out, Value::Dense(vec![16.0]));
    }

    #[test]
    fn bucketize_uses_borders() {
        let v = Value::Dense(vec![-5.0, 0.5, 10.0]);
        let out = Op::Bucketize {
            borders: vec![0.0, 1.0, 5.0],
        }
        .apply(&[&v])
        .unwrap();
        if let Value::Sparse { ids, .. } = out {
            assert_eq!(ids, vec![0, 1, 3]);
        } else {
            panic!()
        }
    }

    #[test]
    fn sigridhash_bounds_and_determinism() {
        let v = sparse(vec![vec![1, 2, 3], vec![999]]);
        let op = Op::SigridHash {
            salt: 7,
            modulus: 100,
        };
        let a = op.apply(&[&v]).unwrap();
        let b = op.apply(&[&v]).unwrap();
        assert_eq!(a, b);
        if let Value::Sparse { ids, offsets, .. } = a {
            assert!(ids.iter().all(|&id| id < 100));
            assert_eq!(offsets, vec![0, 3, 4]);
        } else {
            panic!()
        }
    }

    #[test]
    fn firstx_truncates_rows() {
        let v = sparse(vec![vec![1, 2, 3, 4], vec![5], vec![]]);
        let out = Op::FirstX { x: 2 }.apply(&[&v]).unwrap();
        if let Value::Sparse { offsets, ids, .. } = out {
            assert_eq!(offsets, vec![0, 2, 3, 3]);
            assert_eq!(ids, vec![1, 2, 5]);
        } else {
            panic!()
        }
    }

    #[test]
    fn enumerate_positions() {
        let v = sparse(vec![vec![9, 9, 9], vec![4]]);
        let out = Op::Enumerate.apply(&[&v]).unwrap();
        if let Value::Sparse { ids, .. } = out {
            assert_eq!(ids, vec![0, 1, 2, 0]);
        } else {
            panic!()
        }
    }

    #[test]
    fn positive_modulus() {
        let v = sparse(vec![vec![10, 11, 23]]);
        let out = Op::PositiveModulus { modulus: 10 }.apply(&[&v]).unwrap();
        if let Value::Sparse { ids, .. } = out {
            assert_eq!(ids, vec![0, 1, 3]);
        } else {
            panic!()
        }
    }

    #[test]
    fn mapid_with_default() {
        let mut mapping = HashMap::new();
        mapping.insert(5u64, 50u64);
        let v = sparse(vec![vec![5, 6]]);
        let out = Op::MapId {
            mapping,
            default: 99,
        }
        .apply(&[&v])
        .unwrap();
        if let Value::Sparse { ids, .. } = out {
            assert_eq!(ids, vec![50, 99]);
        } else {
            panic!()
        }
    }

    #[test]
    fn ngram_windows() {
        let v = sparse(vec![vec![1, 2, 3], vec![7]]);
        let out = Op::NGram { n: 2 }.apply(&[&v]).unwrap();
        if let Value::Sparse { offsets, ids, .. } = out {
            assert_eq!(offsets, vec![0, 2, 2]); // 2 bigrams; short row none
            assert_eq!(ids.len(), 2);
            assert_ne!(ids[0], ids[1]);
        } else {
            panic!()
        }
    }

    #[test]
    fn cartesian_row_product() {
        let a = sparse(vec![vec![1, 2]]);
        let b = sparse(vec![vec![10, 20, 30]]);
        let out = Op::Cartesian.apply(&[&a, &b]).unwrap();
        if let Value::Sparse { ids, .. } = out {
            assert_eq!(ids.len(), 6);
        } else {
            panic!()
        }
    }

    #[test]
    fn idlist_intersection() {
        let a = sparse(vec![vec![3, 1, 2], vec![5]]);
        let b = sparse(vec![vec![2, 3, 9], vec![6]]);
        let out = Op::IdListTransform.apply(&[&a, &b]).unwrap();
        if let Value::Sparse { offsets, ids, .. } = out {
            assert_eq!(ids, vec![2, 3]);
            assert_eq!(offsets, vec![0, 2, 2]);
        } else {
            panic!()
        }
    }

    #[test]
    fn compute_score_affine() {
        let v = Value::Sparse {
            offsets: vec![0, 2],
            ids: vec![1, 2],
            scores: Some(vec![0.5, 1.0]),
        };
        let out = Op::ComputeScore { mul: 2.0, add: 1.0 }.apply(&[&v]).unwrap();
        if let Value::Sparse { scores, .. } = out {
            assert_eq!(scores.unwrap(), vec![2.0, 3.0]);
        } else {
            panic!()
        }
    }

    #[test]
    fn sampling_mask_rate() {
        let v = Value::Dense(vec![0.0; 10_000]);
        let out = Op::Sampling {
            rate: 0.25,
            seed: 3,
        }
        .apply(&[&v])
        .unwrap();
        if let Value::Dense(mask) = out {
            let kept: f32 = mask.iter().sum();
            let frac = kept / 10_000.0;
            assert!((frac - 0.25).abs() < 0.02, "{frac}");
        } else {
            panic!()
        }
    }

    #[test]
    fn onehot_bucket_bounds() {
        let v = Value::Dense(vec![0.1, -3.5, 100.0]);
        let out = Op::Onehot { buckets: 16 }.apply(&[&v]).unwrap();
        if let Value::Sparse { ids, offsets, .. } = out {
            assert_eq!(offsets, vec![0, 1, 2, 3]);
            assert!(ids.iter().all(|&id| id < 16));
        } else {
            panic!()
        }
    }

    #[test]
    fn type_and_arity_errors() {
        let d = Value::Dense(vec![1.0]);
        let s = sparse(vec![vec![1]]);
        assert!(Op::Logit { eps: 0.01 }.apply(&[&s]).is_err());
        assert!(Op::SigridHash { salt: 0, modulus: 10 }.apply(&[&d]).is_err());
        assert!(Op::Cartesian.apply(&[&s]).is_err());
        let mismatched = sparse(vec![vec![1], vec![2]]);
        assert!(Op::Cartesian.apply(&[&s, &mismatched]).is_err());
    }

    #[test]
    fn class_assignment_covers_all_ops() {
        assert_eq!(all_op_names().len(), 16);
        assert_eq!(Op::NGram { n: 2 }.class(), OpClass::FeatureGen);
        assert_eq!(
            Op::SigridHash { salt: 0, modulus: 1 }.class(),
            OpClass::SparseNorm
        );
        assert_eq!(Op::Logit { eps: 0.1 }.class(), OpClass::DenseNorm);
    }
}
