//! Per-feature transform DAGs (§7.2): "a single feature X may require a
//! DAG of multiple operations that apply Bucketize to feature A, apply
//! FirstX to feature B, compute the NGram of the intermediate values, and
//! apply SigridHash to generate feature X."
//!
//! The executor runs a whole session's DAG over one mini-batch of
//! columnar data, tracking per-class cycle accounting (the Fig 9 /
//! §6.4 breakdown).

use super::{Op, OpClass, Value, XformError};
use crate::config::RmConfig;
use crate::data::ColumnarBatch;
use crate::schema::{FeatureId, FeatureKind, Schema};
use crate::util::rng::Pcg32;
use std::collections::HashMap;
use std::time::Instant;

/// Declared type of a raw input feature — determines what an *absent*
/// column materializes as (features can be missing from a stripe
/// entirely when coverage is low or partitions predate the feature,
/// §4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// Resolve from the batch; absent ⇒ empty sparse.
    Auto,
    /// Absent ⇒ all-zero dense column.
    Dense,
    /// Absent ⇒ empty sparse column.
    Sparse,
}

/// One node in the DAG. Inputs refer to earlier node indices
/// (topological by construction).
#[derive(Clone, Debug)]
pub enum Node {
    /// Read a raw feature column from the batch.
    Input { id: FeatureId, kind: InputKind },
    /// Apply an op to earlier nodes' outputs.
    Apply { op: Op, inputs: Vec<usize> },
}

/// Execution statistics for Fig 9 / §6.4.
#[derive(Clone, Debug, Default)]
pub struct DagStats {
    pub secs_by_class: HashMap<OpClass, f64>,
    pub elements_by_class: HashMap<OpClass, u64>,
    pub ops_run: u64,
}

impl DagStats {
    pub fn total_secs(&self) -> f64 {
        self.secs_by_class.values().sum()
    }

    pub fn class_frac(&self, c: OpClass) -> f64 {
        let t = self.total_secs();
        if t == 0.0 {
            0.0
        } else {
            self.secs_by_class.get(&c).copied().unwrap_or(0.0) / t
        }
    }

    pub fn merge(&mut self, o: &DagStats) {
        for (k, v) in &o.secs_by_class {
            *self.secs_by_class.entry(*k).or_default() += v;
        }
        for (k, v) in &o.elements_by_class {
            *self.elements_by_class.entry(*k).or_default() += v;
        }
        self.ops_run += o.ops_run;
    }
}

/// A session's transform program: nodes + which node feeds each output
/// (derived or normalized) feature.
#[derive(Clone, Debug, Default)]
pub struct TransformDag {
    pub nodes: Vec<Node>,
    /// (output feature id, node index) — these become tensor columns.
    pub outputs: Vec<(FeatureId, usize)>,
}

impl TransformDag {
    pub fn input(&mut self, id: FeatureId) -> usize {
        self.input_kind(id, InputKind::Auto)
    }

    pub fn input_dense(&mut self, id: FeatureId) -> usize {
        self.input_kind(id, InputKind::Dense)
    }

    pub fn input_sparse(&mut self, id: FeatureId) -> usize {
        self.input_kind(id, InputKind::Sparse)
    }

    pub fn input_kind(&mut self, id: FeatureId, kind: InputKind) -> usize {
        self.nodes.push(Node::Input { id, kind });
        self.nodes.len() - 1
    }

    pub fn apply(&mut self, op: Op, inputs: Vec<usize>) -> usize {
        for &i in &inputs {
            assert!(i < self.nodes.len(), "forward reference in DAG");
        }
        self.nodes.push(Node::Apply { op, inputs });
        self.nodes.len() - 1
    }

    pub fn output(&mut self, id: FeatureId, node: usize) {
        self.outputs.push((id, node));
    }

    /// Whether any op's output depends on the *row index* rather than
    /// only the row's feature values (today: `Sampling`, whose keep-mask
    /// hashes the row position). Such DAGs must not be evaluated over
    /// deduplicated unique-payload batches — the dedup-aware DPP path
    /// checks this and falls back to the duplication-oblivious path.
    pub fn row_index_sensitive(&self) -> bool {
        self.nodes.iter().any(|n| {
            matches!(
                n,
                Node::Apply {
                    op: super::Op::Sampling { .. },
                    ..
                }
            )
        })
    }

    /// The raw features the DAG needs from storage (the projection).
    pub fn required_inputs(&self) -> Vec<FeatureId> {
        let mut v: Vec<FeatureId> = self
            .nodes
            .iter()
            .filter_map(|n| match n {
                Node::Input { id, .. } => Some(*id),
                _ => None,
            })
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Execute over one batch; returns output columns + stats.
    pub fn execute(
        &self,
        batch: &ColumnarBatch,
    ) -> Result<(Vec<(FeatureId, Value)>, DagStats), XformError> {
        // Evaluate every node (even ones feeding no output), preserving
        // the historical stats accounting.
        let all: Vec<usize> = (0..self.nodes.len()).collect();
        let (slots, stats) = self.execute_subset(batch, &all)?;
        let outputs = self
            .outputs
            .iter()
            .map(|&(id, n)| (id, slots[n].clone().expect("output slot")))
            .collect();
        Ok((outputs, stats))
    }

    /// Execute only the nodes in `wanted` plus their ancestors — the
    /// partial-evaluation entry the cross-job transform cache uses when
    /// some outputs were served from cache and only the missing
    /// sub-DAGs still need CPU. Returns the full slot vector (skipped
    /// nodes stay `None`) and stats covering only the ops actually run.
    pub fn execute_subset(
        &self,
        batch: &ColumnarBatch,
        wanted: &[usize],
    ) -> Result<(Vec<Option<Value>>, DagStats), XformError> {
        let mut need = vec![false; self.nodes.len()];
        let mut stack: Vec<usize> = wanted.to_vec();
        while let Some(i) = stack.pop() {
            if need[i] {
                continue;
            }
            need[i] = true;
            if let Node::Apply { inputs, .. } = &self.nodes[i] {
                stack.extend(inputs.iter().copied());
            }
        }
        let mut slots: Vec<Option<Value>> = vec![None; self.nodes.len()];
        let mut stats = DagStats::default();
        for (i, node) in self.nodes.iter().enumerate() {
            if !need[i] {
                continue;
            }
            match node {
                Node::Input { id, kind } => {
                    let v = if let Some(c) =
                        batch.dense.iter().find(|c| c.id == *id)
                    {
                        Value::Dense(c.expand(0.0))
                    } else if let Some(c) =
                        batch.sparse.iter().find(|c| c.id == *id)
                    {
                        Value::Sparse {
                            offsets: c.offsets.clone(),
                            ids: c.ids.clone(),
                            scores: c.scores.clone(),
                        }
                    } else {
                        // Missing feature (absent from this stripe / old
                        // partition, §4.3): typed default.
                        match kind {
                            InputKind::Dense => {
                                Value::Dense(vec![0.0; batch.num_rows])
                            }
                            _ => Value::empty_sparse(batch.num_rows),
                        }
                    };
                    slots[i] = Some(v);
                }
                Node::Apply { op, inputs } => {
                    let ins: Vec<&Value> = inputs
                        .iter()
                        .map(|&j| slots[j].as_ref().expect("topo order"))
                        .collect();
                    let t = Instant::now();
                    let out = op.apply(&ins)?;
                    let dt = t.elapsed().as_secs_f64();
                    let class = op.class();
                    *stats.secs_by_class.entry(class).or_default() += dt;
                    *stats.elements_by_class.entry(class).or_default() +=
                        out.elements() as u64;
                    stats.ops_run += 1;
                    slots[i] = Some(out);
                }
            }
        }
        Ok((slots, stats))
    }
}

/// Build a representative per-RM session DAG over a materialized schema:
/// normalization for every used feature plus `derived` feature-generation
/// chains shaped like the paper's example (Bucketize + FirstX → NGram →
/// SigridHash), with op counts tuned by the RM's intensity so the cycle
/// mix lands near the §6.4 split.
pub fn session_dag(rng: &mut Pcg32, rm: &RmConfig, schema: &Schema, projection: &[FeatureId]) -> TransformDag {
    let mut dag = TransformDag::default();
    let mut dense_nodes: Vec<(FeatureId, usize)> = Vec::new();
    let mut sparse_nodes: Vec<(FeatureId, usize)> = Vec::new();

    for &fid in projection {
        let Some(def) = schema.by_id(fid) else { continue };
        let node = match def.kind {
            FeatureKind::Dense => dag.input_dense(fid),
            _ => dag.input_sparse(fid),
        };
        match def.kind {
            FeatureKind::Dense => {
                // Dense normalization chain: clamp → (logit | boxcox).
                let c = dag.apply(
                    Op::Clamp {
                        lo: -100.0,
                        hi: 100.0,
                    },
                    vec![node],
                );
                let n = if rng.chance(0.5) {
                    dag.apply(Op::Logit { eps: 1e-4 }, vec![c])
                } else {
                    dag.apply(Op::BoxCox { lambda: 0.5 }, vec![c])
                };
                dag.output(fid, n);
                dense_nodes.push((fid, n));
            }
            FeatureKind::Sparse | FeatureKind::ScoredSparse => {
                // Sparse normalization: FirstX → SigridHash.
                let f = dag.apply(Op::FirstX { x: 64 }, vec![node]);
                let h = dag.apply(
                    Op::SigridHash {
                        salt: fid.0 as u64,
                        modulus: 100_000,
                    },
                    vec![f],
                );
                dag.output(fid, h);
                sparse_nodes.push((fid, h));
            }
        }
    }

    // Derived features: feature-generation chains (the expensive 75%).
    // Scale count by the RM's derived-feature share and intensity.
    let derived_frac =
        rm.derived_features as f64 / rm.used_features().max(1) as f64;
    let n_derived = ((projection.len() as f64 * derived_frac)
        * rm.transform_intensity)
        .round()
        .max(if rm.derived_features > 0 { 1.0 } else { 0.0 })
        as usize;
    let derived_base = 1 << 20; // synthetic id namespace for derived feats
    for d in 0..n_derived {
        let out_id = FeatureId((derived_base + d) as u32);
        match (
            sparse_nodes.is_empty(),
            dense_nodes.is_empty(),
            rng.below(4),
        ) {
            (false, false, 0) => {
                // Bucketize(dense) ⊗ sparse → NGram → SigridHash
                let (_, dn) = *rng.choose(&dense_nodes);
                let (_, sn) = *rng.choose(&sparse_nodes);
                let b = dag.apply(
                    Op::Bucketize {
                        borders: vec![-2.0, -1.0, 0.0, 1.0, 2.0],
                    },
                    vec![dn],
                );
                let c = dag.apply(Op::Cartesian, vec![b, sn]);
                let h = dag.apply(
                    Op::SigridHash {
                        salt: d as u64,
                        modulus: 65_536,
                    },
                    vec![c],
                );
                dag.output(out_id, h);
            }
            (false, _, 1) => {
                // NGram chain.
                let (_, sn) = *rng.choose(&sparse_nodes);
                let g = dag.apply(Op::NGram { n: 2 }, vec![sn]);
                let h = dag.apply(
                    Op::SigridHash {
                        salt: 7 + d as u64,
                        modulus: 65_536,
                    },
                    vec![g],
                );
                dag.output(out_id, h);
            }
            (false, _, 2) if sparse_nodes.len() >= 2 => {
                // Intersection of two lists → MapId.
                let (_, a) = *rng.choose(&sparse_nodes);
                let (_, b) = *rng.choose(&sparse_nodes);
                let i = dag.apply(Op::IdListTransform, vec![a, b]);
                let m = dag.apply(
                    Op::MapId {
                        mapping: HashMap::new(),
                        default: 1,
                    },
                    vec![i],
                );
                dag.output(out_id, m);
            }
            (_, false, _) => {
                // Bucketize + Onehot from dense.
                let (_, dn) = *rng.choose(&dense_nodes);
                let b = dag.apply(
                    Op::Bucketize {
                        borders: (0..16).map(|i| i as f32 / 4.0 - 2.0).collect(),
                    },
                    vec![dn],
                );
                dag.output(out_id, b);
            }
            _ => {}
        }
    }
    dag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;
    use crate::data::{Sample, SparseValue};

    fn batch() -> ColumnarBatch {
        let samples: Vec<Sample> = (0..8u64)
            .map(|i| {
                let mut s = Sample {
                    dense: vec![(FeatureId(0), i as f32 / 8.0)],
                    sparse: vec![(
                        FeatureId(10),
                        SparseValue::ids(vec![i, i + 1, i + 2]),
                    )],
                    label: 0.0,
                    timestamp: i,
                };
                s.sort_features();
                s
            })
            .collect();
        ColumnarBatch::from_samples(&samples, &[FeatureId(0)], &[FeatureId(10)])
    }

    #[test]
    fn simple_dag_executes() {
        let mut dag = TransformDag::default();
        let d = dag.input(FeatureId(0));
        let c = dag.apply(Op::Clamp { lo: 0.0, hi: 0.5 }, vec![d]);
        dag.output(FeatureId(0), c);
        let s = dag.input(FeatureId(10));
        let h = dag.apply(
            Op::SigridHash {
                salt: 1,
                modulus: 50,
            },
            vec![s],
        );
        dag.output(FeatureId(10), h);

        let (outs, stats) = dag.execute(&batch()).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].1.rows(), 8);
        assert_eq!(stats.ops_run, 2);
        assert!(stats.total_secs() >= 0.0);
    }

    #[test]
    fn paper_example_dag() {
        // Bucketize(A) + FirstX(B) → NGram → SigridHash = feature X (§7.2).
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(0));
        let b = dag.input(FeatureId(10));
        let ba = dag.apply(
            Op::Bucketize {
                borders: vec![0.25, 0.5, 0.75],
            },
            vec![a],
        );
        let fb = dag.apply(Op::FirstX { x: 2 }, vec![b]);
        let cross = dag.apply(Op::Cartesian, vec![ba, fb]);
        let ng = dag.apply(Op::NGram { n: 2 }, vec![cross]);
        let x = dag.apply(
            Op::SigridHash {
                salt: 9,
                modulus: 1000,
            },
            vec![ng],
        );
        dag.output(FeatureId(999), x);
        let (outs, stats) = dag.execute(&batch()).unwrap();
        assert_eq!(outs.len(), 1);
        if let Value::Sparse { ids, .. } = &outs[0].1 {
            assert!(ids.iter().all(|&i| i < 1000));
        } else {
            panic!()
        }
        assert!(stats.class_frac(OpClass::FeatureGen) > 0.0);
    }

    #[test]
    fn row_index_sensitivity_detects_sampling() {
        let mut dag = TransformDag::default();
        let s = dag.input(FeatureId(10));
        let h = dag.apply(
            Op::SigridHash {
                salt: 1,
                modulus: 10,
            },
            vec![s],
        );
        dag.output(FeatureId(10), h);
        assert!(!dag.row_index_sensitive());
        let z = dag.apply(Op::Sampling { rate: 0.5, seed: 3 }, vec![h]);
        dag.output(FeatureId(11), z);
        assert!(dag.row_index_sensitive());
    }

    #[test]
    fn required_inputs_dedup() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(5));
        let b = dag.input(FeatureId(5));
        let c = dag.input(FeatureId(3));
        dag.apply(Op::Cartesian, vec![a, b]);
        dag.apply(Op::FirstX { x: 1 }, vec![c]);
        assert_eq!(
            dag.required_inputs(),
            vec![FeatureId(3), FeatureId(5)]
        );
    }

    #[test]
    fn missing_input_becomes_empty_sparse() {
        let mut dag = TransformDag::default();
        let m = dag.input(FeatureId(777)); // not in batch
        let h = dag.apply(
            Op::SigridHash {
                salt: 0,
                modulus: 10,
            },
            vec![m],
        );
        dag.output(FeatureId(777), h);
        let (outs, _) = dag.execute(&batch()).unwrap();
        assert_eq!(outs[0].1.elements(), 0);
        assert_eq!(outs[0].1.rows(), 8);
    }

    #[test]
    fn session_dag_generates_for_all_rms() {
        let mut rng = Pcg32::new(11);
        for id in RmId::ALL {
            let rm = RmConfig::get(id);
            let schema = Schema::synthetic(&mut rng, 40, 20, 0.5, 10.0);
            let proj: Vec<FeatureId> =
                schema.features.iter().take(20).map(|f| f.id).collect();
            let dag = session_dag(&mut rng, &rm, &schema, &proj);
            assert!(!dag.outputs.is_empty(), "{}", rm.id.name());
            let (outs, stats) = dag.execute(&batch_for(&schema, &proj)).unwrap();
            assert!(!outs.is_empty());
            assert!(stats.ops_run > 0);
            // Structural check (cycle fractions are timing-noisy at tiny
            // batch sizes; the §6.4 split is reported at realistic sizes
            // by bench_transforms): RM1's DAG must contain a substantial
            // number of feature-generation ops.
            if id == RmId::Rm1 {
                let fg_ops = dag
                    .nodes
                    .iter()
                    .filter(|n| {
                        matches!(n, Node::Apply { op, .. }
                            if op.class() == OpClass::FeatureGen)
                    })
                    .count();
                assert!(fg_ops >= 5, "only {fg_ops} feature-gen ops");
            }
        }
    }

    fn batch_for(schema: &Schema, proj: &[FeatureId]) -> ColumnarBatch {
        let mut rng = Pcg32::new(5);
        let samples =
            crate::datagen::generate_partition_samples(&mut rng, schema, 16, 0);
        let dense: Vec<FeatureId> = proj
            .iter()
            .filter(|f| {
                matches!(
                    schema.by_id(**f).map(|d| d.kind),
                    Some(FeatureKind::Dense)
                )
            })
            .copied()
            .collect();
        let sparse: Vec<FeatureId> = proj
            .iter()
            .filter(|f| {
                !matches!(
                    schema.by_id(**f).map(|d| d.kind),
                    Some(FeatureKind::Dense)
                )
            })
            .copied()
            .collect();
        ColumnarBatch::from_samples(&samples, &dense, &sparse)
    }
}
