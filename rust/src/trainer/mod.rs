//! Trainer model: GPU ingestion demand (Table 8), data-stall accounting
//! for colocated preprocessing (Table 7), and the PJRT-backed training
//! loop that consumes DPP tensors for real (the end-to-end example).

use crate::config::{RmConfig, TrainerNodeSpec};
use crate::resources::{PerSampleCost, HOST_CORE_EQUIV};

/// GPU-side ingestion demand for one 8-GPU training node.
#[derive(Clone, Copy, Debug)]
pub struct TrainerDemand {
    /// Preprocessed-tensor ingestion rate, GB/s per node (Table 8).
    pub gbps_per_node: f64,
    /// Average preprocessed bytes per sample (from the live pipeline).
    pub bytes_per_sample: f64,
}

impl TrainerDemand {
    pub fn for_rm(rm: &RmConfig, bytes_per_sample: f64) -> TrainerDemand {
        TrainerDemand {
            gbps_per_node: rm.trainer_node_gbps,
            bytes_per_sample,
        }
    }

    /// Samples/s the node's GPUs demand.
    pub fn samples_per_sec(&self) -> f64 {
        self.gbps_per_node * 1e9 / self.bytes_per_sample.max(1.0)
    }
}

/// Colocated-preprocessing analysis (the paper's §6 motivation run:
/// preprocessing on the trainer host's own CPUs → Table 7's 56% stall).
#[derive(Clone, Copy, Debug)]
pub struct ColocatedReport {
    /// Fraction of GPU cycles stalled waiting for data.
    pub gpu_stall_frac: f64,
    /// Host CPU utilization while preprocessing.
    pub cpu_util: f64,
    /// Host memory-bandwidth utilization.
    pub mem_bw_util: f64,
    /// Achievable vs demanded samples/s.
    pub achievable_sps: f64,
    pub demanded_sps: f64,
}

/// Model a training node doing its own preprocessing: demand comes from
/// the GPUs (Table 8); supply from running the measured pipeline on the
/// host cores (minus a reserve for the training framework itself).
pub fn colocated_preprocessing(
    demand: &TrainerDemand,
    cost: &PerSampleCost,
    node: &TrainerNodeSpec,
    framework_core_reserve: f64,
) -> ColocatedReport {
    let cores = node.total_cores() as f64 - framework_core_reserve;
    let cpu_capacity_sps = cores / (cost.cpu_secs / HOST_CORE_EQUIV).max(1e-18);
    let membw_capacity_sps = crate::resources::MEMBW_PRACTICAL_FRAC
        * node.peak_mem_bw_gbps
        * 1e9
        / cost.mem_bytes.max(1.0);
    let achievable = cpu_capacity_sps.min(membw_capacity_sps);
    let demanded = demand.samples_per_sec();
    let served = achievable.min(demanded);
    let stall = (1.0 - served / demanded).max(0.0);
    // Utilizations at the served rate.
    let cpu_util = (served / cpu_capacity_sps).min(1.0);
    let mem_bw_util = served * cost.mem_bytes / (node.peak_mem_bw_gbps * 1e9);
    ColocatedReport {
        gpu_stall_frac: stall,
        cpu_util,
        mem_bw_util,
        achievable_sps: achievable,
        demanded_sps: demanded,
    }
}

/// Number of DPP workers (on a given worker saturation throughput) needed
/// to keep one trainer node unstalled — Table 9's last column.
pub fn workers_per_trainer(demand_sps: f64, worker_sps: f64) -> f64 {
    demand_sps / worker_sps.max(1e-18)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId};

    fn rm1_like_cost() -> PerSampleCost {
        // Shaped like a measured RM1 pipeline: expensive transforms.
        PerSampleCost {
            cpu_secs: 2.4e-4,
            mem_bytes: 8e5,
            net_rx_bytes: 7e4,
            net_tx_bytes: 6e4,
            resident_bytes: 1e5,
            frac_extract: 0.25,
            frac_transform: 0.65,
            frac_misc: 0.10,
        }
    }

    #[test]
    fn demand_rates_track_table8() {
        let rm1 = RmConfig::get(RmId::Rm1);
        let rm2 = RmConfig::get(RmId::Rm2);
        let d1 = TrainerDemand::for_rm(&rm1, 60_000.0);
        let d2 = TrainerDemand::for_rm(&rm2, 60_000.0);
        // RM1 demands 16.5/4.69 ≈ 3.5x the samples of RM2 at equal
        // sample size.
        let ratio = d1.samples_per_sec() / d2.samples_per_sec();
        assert!((ratio - 16.50 / 4.69).abs() < 0.01);
    }

    #[test]
    fn colocated_preprocessing_stalls_heavy_models() {
        // Table 7's setup: RM1 on the 2-socket V100 node.
        let rm1 = RmConfig::get(RmId::Rm1);
        let demand = TrainerDemand::for_rm(&rm1, 60_000.0);
        let r = colocated_preprocessing(
            &demand,
            &rm1_like_cost(),
            &TrainerNodeSpec::v100_node(),
            4.0,
        );
        assert!(
            r.gpu_stall_frac > 0.3,
            "expected heavy stalls, got {}",
            r.gpu_stall_frac
        );
        assert!(r.cpu_util > 0.85, "CPUs should be pegged: {}", r.cpu_util);
        assert!(r.achievable_sps < r.demanded_sps);
    }

    #[test]
    fn light_demand_does_not_stall() {
        let demand = TrainerDemand {
            gbps_per_node: 0.05,
            bytes_per_sample: 60_000.0,
        };
        let r = colocated_preprocessing(
            &demand,
            &rm1_like_cost(),
            &TrainerNodeSpec::v100_node(),
            4.0,
        );
        assert!(r.gpu_stall_frac < 1e-9);
        assert!(r.cpu_util < 1.0);
    }

    #[test]
    fn workers_per_trainer_scales_with_demand() {
        assert!((workers_per_trainer(1000.0, 100.0) - 10.0).abs() < 1e-9);
        assert!(workers_per_trainer(50.0, 100.0) < 1.0);
    }
}
