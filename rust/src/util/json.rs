//! Minimal JSON value model + serializer + parser (no serde in the
//! offline env).
//!
//! Only what the experiment reports need: objects, arrays, strings,
//! numbers, bools. Output is deterministic (insertion-ordered objects);
//! [`Json::parse`] round-trips anything the serializer emits (used to
//! self-validate trace exports before they are written to disk).

use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(entries) => {
                let value = value.into();
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Parse a JSON document. Handles everything the serializer emits
    /// (and standard JSON generally: escapes, `\uXXXX` with surrogate
    /// pairs, nested containers); errors carry a byte offset.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    Json::Str(k.clone()).write(out, indent, false);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !entries.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if matches!(c, b' ' | b'\t' | b'\n' | b'\r') {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected input at byte {}", self.i)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            let numeric = c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E');
            if numeric {
                self.i += 1;
            } else {
                break;
            }
        }
        self.utf8(start, self.i)?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        let mut run = self.i;
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    out.push_str(self.utf8(run, self.i)?);
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    out.push_str(self.utf8(run, self.i)?);
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => out.push(self.unicode_escape()?),
                        _ => {
                            return Err(format!(
                                "bad escape at byte {}",
                                self.i - 1
                            ));
                        }
                    }
                    run = self.i;
                }
                Some(_) => self.i += 1,
            }
        }
    }

    /// The four hex digits after `\u`, combining surrogate pairs.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        let c = if (0xD800..0xDC00).contains(&hi) {
            if self.b[self.i..].starts_with(b"\\u") {
                self.i += 2;
                let lo = self.hex4()?;
                if (0xDC00..0xE000).contains(&lo) {
                    let cp =
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    char::from_u32(cp)
                } else {
                    None
                }
            } else {
                None
            }
        } else {
            char::from_u32(hi)
        };
        c.ok_or_else(|| format!("bad \\u escape before byte {}", self.i))
    }

    fn utf8(&self, from: usize, to: usize) -> Result<&'a str, String> {
        std::str::from_utf8(&self.b[from..to])
            .map_err(|_| format!("invalid utf-8 at byte {from}"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let t = self.utf8(self.i, self.i + 4)?;
        let v = u32::from_str_radix(t, 16)
            .map_err(|_| format!("bad hex at byte {}", self.i))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}",
                        self.i
                    ));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            entries.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(entries));
                }
                _ => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}",
                        self.i
                    ));
                }
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shape() {
        let mut j = Json::obj();
        j.set("name", "RM1").set("gbps", 16.5).set("ok", true);
        j.set("xs", vec![1u64, 2, 3]);
        let s = j.to_string_pretty();
        assert!(s.contains("\"name\": \"RM1\""));
        assert!(s.contains("16.5"));
        assert!(s.contains("[\n"));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_pretty(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string_pretty(), "3");
        assert_eq!(Json::Num(3.5).to_string_pretty(), "3.5");
    }

    #[test]
    fn set_replaces_existing() {
        let mut j = Json::obj();
        j.set("k", 1u64);
        j.set("k", 2u64);
        assert_eq!(j.get("k").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn parse_roundtrips_serializer_output() {
        let mut inner = Json::obj();
        inner.set("quote\"back\\slash\nnl", "é 中 ok");
        inner.set("neg", -12.75);
        inner.set("big", 1e300);
        inner.set("tiny", 4.9e-10);
        let mut j = Json::obj();
        j.set("name", "RM1")
            .set("none", Json::Null)
            .set("flag", false)
            .set("n", 18_446_744_073_709u64)
            .set("xs", vec![1u64, 2, 3])
            .set("nested", Json::Arr(vec![inner, Json::Arr(vec![])]))
            .set("empty_obj", Json::obj());
        let s = j.to_string_pretty();
        assert_eq!(Json::parse(&s), Ok(j));
    }

    #[test]
    fn parse_handles_compact_and_escapes() {
        let j = Json::parse(
            "{\"a\":[1,2.5,null,true],\"s\":\"x\\u0041\\n\\ud83d\\ude00\"}",
        )
        .unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("xA\n\u{1F600}"));
        let xs = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(xs.len(), 4);
        assert_eq!(xs[1].as_f64(), Some(2.5));
        assert_eq!(xs[2], Json::Null);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("\"bad \\q escape\"").is_err());
    }
}
