//! Minimal JSON value model + serializer (no serde in the offline env).
//!
//! Only what the experiment reports need: objects, arrays, strings,
//! numbers, bools. Output is deterministic (insertion-ordered objects).

use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object; panics on non-objects.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(entries) => {
                let value = value.into();
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty && !xs.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    Json::Str(k.clone()).write(out, indent, false);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !entries.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shape() {
        let mut j = Json::obj();
        j.set("name", "RM1").set("gbps", 16.5).set("ok", true);
        j.set("xs", vec![1u64, 2, 3]);
        let s = j.to_string_pretty();
        assert!(s.contains("\"name\": \"RM1\""));
        assert!(s.contains("16.5"));
        assert!(s.contains("[\n"));
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_pretty(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string_pretty(), "3");
        assert_eq!(Json::Num(3.5).to_string_pretty(), "3.5");
    }

    #[test]
    fn set_replaces_existing() {
        let mut j = Json::obj();
        j.set("k", 1u64);
        j.set("k", 2u64);
        assert_eq!(j.get("k").unwrap().as_f64(), Some(2.0));
    }
}
