//! Small self-contained utilities.
//!
//! The build environment is fully offline and only ships the `xla` crate's
//! vendored dependency closure — no `rand`, `serde`, `clap`, `criterion`, or
//! `proptest`. This module provides deterministic, minimal functional
//! equivalents (see DESIGN.md substitution table):
//!
//! * [`rng`] — SplitMix64 / PCG32 PRNGs and the distributions the dataset
//!   generator needs (uniform, normal, lognormal, zipf, ...).
//! * [`stats`] — online moments, percentiles, log-bucketed histograms.
//! * [`json`] — a tiny JSON value model + writer for machine-readable
//!   experiment reports.
//! * [`cli`] — a `--flag value` argument parser for the `dsi` binary.
//! * [`timing`] — wallclock timing + a micro-bench harness used by the
//!   `harness = false` bench targets.
//! * [`prop`] — a miniature property-testing harness (seed-reporting,
//!   bounded shrinking over the case index).
//! * [`bytes`] — varint/zigzag codecs and human-readable byte formatting.
//! * [`bench`] — publishes bench results JSON to `target/` and the
//!   repo-root `BENCH_*.json` perf trajectory.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timing;
