//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath on this
//! image — the same code runs for real in `rust/tests/proptests.rs`):
//! ```no_run
//! use dsi::util::prop::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let xs = g.vec_u64(0..64, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     if ys == xs { Ok(()) } else { Err(format!("mismatch for {xs:?}")) }
//! });
//! ```
//!
//! On failure the harness retries the failing case at progressively smaller
//! `size` values (a bounded shrink over the generator's size budget) and
//! panics with the smallest failing seed + size so the case is trivially
//! reproducible.

use super::rng::Pcg32;
use std::ops::Range;

/// Value generator handed to each property case. `size` scales collection
/// lengths so shrinking can retry with smaller structures.
pub struct Gen {
    pub rng: Pcg32,
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            size,
        }
    }

    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        self.rng.range(range.start, range.end)
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.rng.range(range.start as u64, range.end as u64) as usize
    }

    pub fn f32(&mut self) -> f32 {
        // Mix of regular, small, large, and special-ish values.
        match self.rng.below(8) {
            0 => 0.0,
            1 => -1.0,
            2 => self.rng.f32() * 1e-6,
            3 => self.rng.f32() * 1e6,
            _ => self.rng.f32() * 2.0 - 1.0,
        }
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Collection length scaled by the shrink budget.
    pub fn len(&mut self, max: usize) -> usize {
        let cap = ((max as f64) * self.size).ceil().max(1.0) as usize;
        self.usize(0..cap + 1)
    }

    pub fn vec_u64(&mut self, each: Range<u64>, max_len: usize) -> Vec<u64> {
        let n = self.len(max_len);
        (0..n).map(|_| self.u64(each.clone())).collect()
    }

    pub fn vec_f32(&mut self, max_len: usize) -> Vec<f32> {
        let n = self.len(max_len);
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn bytes(&mut self, max_len: usize) -> Vec<u8> {
        let n = self.len(max_len);
        (0..n).map(|_| self.rng.next_u32() as u8).collect()
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.len(max_len);
        (0..n)
            .map(|_| (b'a' + (self.rng.below(26) as u8)) as char)
            .collect()
    }
}

/// Run `cases` random cases of property `f`. Panics with a reproducible
/// seed on failure (after attempting to shrink the size budget).
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    // Honor an env knob so CI can crank cases up.
    let cases = std::env::var("DSI_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    let base_seed = 0xD51C0DE ^ hash_name(name);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = f(&mut g) {
            // Shrink: retry the same seed with smaller size budgets and
            // report the smallest size that still fails.
            let mut fail_size = 1.0;
            let mut fail_msg = msg;
            for &size in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let mut g = Gen::new(seed, size);
                if let Err(m) = f(&mut g) {
                    fail_size = size;
                    fail_msg = m;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 size {fail_size}): {fail_msg}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 50, |g| {
            let a = g.u64(0..1000);
            let b = g.u64(0..1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let v = g.u64(10..20);
            if !(10..20).contains(&v) {
                return Err(format!("u64 out of range: {v}"));
            }
            let xs = g.vec_u64(0..5, 16);
            if xs.len() > 17 {
                return Err("vec too long".into());
            }
            if xs.iter().any(|&x| x >= 5) {
                return Err("element out of range".into());
            }
            Ok(())
        });
    }
}
