//! Repo-root publication of machine-readable bench results.
//!
//! Every `harness = false` bench emits two copies of its results JSON:
//! `target/<name>_results.json` (build-local, consumed by the CI
//! collect step and the telemetry artifacts) and `BENCH_<name>.json` at
//! the repository root — the perf-trajectory baseline. Publishing from
//! the bench itself, rather than only from a hosted-CI copy step, means
//! any environment that runs a bench grows the trajectory.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// The repository root: the parent of this crate's manifest directory.
/// Falls back to the current directory for a crate checked out bare.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .to_path_buf()
}

/// Write `results` to `target/<name>_results.json` and to
/// `<repo-root>/BENCH_<name>.json`, returning every path that was
/// actually written. A read-only checkout may reject the repo-root
/// copy; the bench still counts as published on the `target/` copy
/// alone, so neither write aborts the run.
pub fn publish_results(name: &str, results: &Json) -> Vec<String> {
    let pretty = results.to_string_pretty();
    let mut written = Vec::new();
    let _ = std::fs::create_dir_all("target");
    let local = format!("target/{name}_results.json");
    if std::fs::write(&local, &pretty).is_ok() {
        written.push(local);
    }
    let root = repo_root().join(format!("BENCH_{name}.json"));
    if std::fs::write(&root, &pretty).is_ok() {
        written.push(root.display().to_string());
    }
    written
}
