//! Byte-level codecs shared by the DWRF format and the RPC framing:
//! LEB128 varints, zigzag, fixed-width little-endian helpers, and
//! human-readable size formatting for reports.

/// Append a u64 as LEB128 varint.
#[inline]
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a LEB128 varint; returns (value, bytes_consumed).
#[inline]
pub fn get_varint(buf: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in buf.iter().enumerate() {
        if shift >= 64 {
            return None;
        }
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
    }
    None
}

#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[inline]
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn get_u32(buf: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

#[inline]
pub fn get_u64(buf: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(buf[at..at + 8].try_into().unwrap())
}

#[inline]
pub fn get_f32(buf: &[u8], at: usize) -> f32 {
    f32::from_le_bytes(buf[at..at + 4].try_into().unwrap())
}

/// Sequential reader over a byte slice (decode side of the codecs above).
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn varint(&mut self) -> Option<u64> {
        let (v, n) = get_varint(&self.buf[self.pos..])?;
        self.pos += n;
        Some(v)
    }

    pub fn u32(&mut self) -> Option<u32> {
        if self.remaining() < 4 {
            return None;
        }
        let v = get_u32(self.buf, self.pos);
        self.pos += 4;
        Some(v)
    }

    pub fn u64(&mut self) -> Option<u64> {
        if self.remaining() < 8 {
            return None;
        }
        let v = get_u64(self.buf, self.pos);
        self.pos += 8;
        Some(v)
    }

    pub fn f32(&mut self) -> Option<f32> {
        if self.remaining() < 4 {
            return None;
        }
        let v = get_f32(self.buf, self.pos);
        self.pos += 4;
        Some(v)
    }

    pub fn bytes(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.remaining() < n {
            return None;
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }
}

/// "16.50 GB/s"-style size formatting for report tables.
pub fn human_bytes(v: f64) -> String {
    const UNITS: [&str; 7] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB", "EiB"];
    let mut v = v;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0} {}", UNITS[u])
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip() {
        let cases = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let (back, n) = get_varint(&buf).unwrap();
            assert_eq!(back, v);
            assert_eq!(n, buf.len());
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [-1i64, 0, 1, -1000, 1000, i64::MIN, i64::MAX] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn byte_reader_sequences() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 300);
        put_u32(&mut buf, 0xdeadbeef);
        put_f32(&mut buf, 1.5);
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.varint(), Some(300));
        assert_eq!(r.u32(), Some(0xdeadbeef));
        assert_eq!(r.f32(), Some(1.5));
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.f32(), None);
    }

    #[test]
    fn human_readable() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_bytes(8.0 * 1024.0 * 1024.0), "8.00 MiB");
    }
}
