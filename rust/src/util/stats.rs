//! Streaming statistics, percentiles, and log-bucketed histograms.
//!
//! Used throughout the characterization harness: Table 6 (I/O size
//! distribution mean/std/p5..p95), Fig 7 (byte-popularity CDF), Fig 8/9
//! (utilization curves), and the §Perf iteration log.

/// Welford online mean/variance plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn sum(&self) -> f64 {
        self.mean * self.n as f64
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact-percentile sample collector (stores values; fine at sim scale).
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs
                .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// `q` in [0, 100]; linear interpolation between closest ranks.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = q / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64)
            .sqrt()
    }
}

/// Power-of-two bucketed histogram for byte sizes / durations.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: Vec<u64>, // bucket i counts values in [2^i, 2^(i+1))
    count: u64,
    sum: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self {
            buckets: vec![0; 64],
            count: 0,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, v: u64) {
        let b = 63 - v.max(1).leading_zeros() as usize;
        self.buckets[b] += 1;
        self.count += 1;
        self.sum += v as f64;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// (bucket_low_bound, count) for non-empty buckets.
    pub fn nonzero(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (1u64 << i, c))
            .collect()
    }
}

/// Build a popularity CDF: given per-item (weight, accesses), returns
/// points (fraction_of_bytes, fraction_of_io) sorted by item popularity
/// (most-accessed first). Exactly the construction of the paper's Fig 7.
pub fn popularity_cdf(items: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let total_w: f64 = items.iter().map(|(w, _)| w).sum();
    let total_a: f64 = items.iter().map(|(_, a)| a).sum();
    if total_w == 0.0 || total_a == 0.0 {
        return vec![];
    }
    let mut sorted: Vec<_> = items.to_vec();
    // Most I/O-per-byte first (popularity density), matching "most-popular
    // x% of stored bytes".
    sorted.sort_by(|a, b| {
        (b.1 / b.0.max(1e-12))
            .partial_cmp(&(a.1 / a.0.max(1e-12)))
            .unwrap()
    });
    let mut out = Vec::with_capacity(sorted.len());
    let (mut cw, mut ca) = (0.0, 0.0);
    for (w, a) in sorted {
        cw += w;
        ca += a;
        out.push((cw / total_w, ca / total_a));
    }
    out
}

/// Interpolate a CDF at x (fraction of bytes) → fraction of I/O.
pub fn cdf_at(cdf: &[(f64, f64)], x: f64) -> f64 {
    if cdf.is_empty() {
        return 0.0;
    }
    let mut prev = (0.0, 0.0);
    for &(bx, by) in cdf {
        if bx >= x {
            let span = bx - prev.0;
            if span <= 0.0 {
                return by;
            }
            let t = (x - prev.0) / span;
            return prev.1 + t * (by - prev.1);
        }
        prev = (bx, by);
    }
    1.0
}

/// Smallest byte-fraction that absorbs at least `io_frac` of I/O.
pub fn bytes_needed_for_io(cdf: &[(f64, f64)], io_frac: f64) -> f64 {
    for &(bx, by) in cdf {
        if by >= io_frac {
            return bx;
        }
    }
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basics() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.std() - 2.138_089_935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i * i % 37) as f64).collect();
        let mut whole = OnlineStats::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        xs[..40].iter().for_each(|&x| a.push(x));
        xs[40..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.std() - whole.std()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((p.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((p.percentile(95.0) - 95.05).abs() < 1e-6);
    }

    #[test]
    fn log_histogram_buckets() {
        let mut h = LogHistogram::new();
        h.push(1);
        h.push(2);
        h.push(3);
        h.push(1024);
        let nz = h.nonzero();
        assert_eq!(nz, vec![(1, 1), (2, 2), (1024, 1)]);
        assert_eq!(h.count(), 4);
    }

    #[test]
    fn popularity_cdf_shape() {
        // 10 items of equal size; one item gets 90% of accesses.
        let mut items = vec![(1.0, 1.0); 10];
        items[0].1 = 81.0; // 81 / 90 = 90%
        let cdf = popularity_cdf(&items);
        // The first 10% of bytes should absorb 90% of I/O.
        assert!((cdf[0].0 - 0.1).abs() < 1e-9);
        assert!((cdf[0].1 - 0.9).abs() < 1e-9);
        assert!((bytes_needed_for_io(&cdf, 0.8) - 0.1).abs() < 1e-9);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_at_interpolates() {
        let cdf = vec![(0.5, 0.8), (1.0, 1.0)];
        assert!((cdf_at(&cdf, 0.25) - 0.4).abs() < 1e-9);
        assert!((cdf_at(&cdf, 0.75) - 0.9).abs() < 1e-9);
    }
}
