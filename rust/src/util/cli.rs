//! Tiny `--flag [value]` argument parser for the `dsi` binary
//! (clap is unavailable in the offline vendored crate set).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (e.g. subcommand names).
    pub positional: Vec<String>,
    /// `--key value` or bare `--key` (stored with empty value).
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Value is next token unless it's another flag.
                    let take = matches!(iter.peek(), Some(n) if !n.starts_with("--"));
                    let v = if take { iter.next().unwrap() } else { String::new() };
                    args.flags.insert(key.to_string(), v);
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).filter(|s| !s.is_empty()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("paper --exp table12 --json --seed 7");
        assert_eq!(a.subcommand(), Some("paper"));
        assert_eq!(a.get("exp"), Some("table12"));
        assert!(a.has("json"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("run --scale=0.5 --out=x.json");
        assert_eq!(a.get_f64("scale", 1.0), 0.5);
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn bare_flag_before_flag() {
        let a = parse("--verbose --n 3");
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some(""));
        assert_eq!(a.get_u64("n", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("bench");
        assert_eq!(a.get_or("exp", "all"), "all");
        assert_eq!(a.get_u64("seed", 42), 42);
    }
}
