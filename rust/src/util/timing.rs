//! Wallclock timing + the micro-bench harness used by the
//! `harness = false` bench targets (criterion is unavailable offline).

use std::time::{Duration, Instant};

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Result of one benchmark: iterations, wall time, optional bytes processed.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub total: Duration,
    pub bytes: u64,
}

impl BenchResult {
    pub fn ns_per_iter(&self) -> f64 {
        self.total.as_nanos() as f64 / self.iters.max(1) as f64
    }

    pub fn throughput_mb_s(&self) -> f64 {
        if self.total.as_secs_f64() == 0.0 {
            return 0.0;
        }
        self.bytes as f64 / 1e6 / self.total.as_secs_f64()
    }

    pub fn report_line(&self) -> String {
        if self.bytes > 0 {
            format!(
                "{:<44} {:>12.1} ns/iter {:>10.1} MB/s ({} iters)",
                self.name,
                self.ns_per_iter(),
                self.throughput_mb_s(),
                self.iters
            )
        } else {
            format!(
                "{:<44} {:>12.1} ns/iter ({} iters)",
                self.name,
                self.ns_per_iter(),
                self.iters
            )
        }
    }
}

/// Criterion-lite: warm up, auto-scale iteration count to ~`budget`,
/// report ns/iter (and MB/s when the closure reports bytes).
pub struct Bench {
    budget: Duration,
    warmup: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Default per-case budget; `BENCH_BUDGET_MS` overrides it so CI
    /// smoke steps can run every bench in seconds instead of minutes.
    const DEFAULT_BUDGET: Duration = Duration::from_millis(700);

    pub fn new() -> Self {
        let budget = std::env::var("BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .map(Duration::from_millis)
            .unwrap_or(Self::DEFAULT_BUDGET);
        let warmup = (budget / 5)
            .max(Duration::from_millis(10))
            .min(Duration::from_millis(150));
        Self {
            budget,
            warmup,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    pub fn budget(&self) -> Duration {
        self.budget
    }

    /// Run `f` repeatedly; `f` returns the number of bytes it processed
    /// (0 if throughput is meaningless for this benchmark).
    pub fn run<F: FnMut() -> u64>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        let mut bytes_per_iter = 0u64;
        while t0.elapsed() < self.warmup || calib_iters == 0 {
            bytes_per_iter = f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let iters = ((self.budget.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(1, 10_000_000);

        let start = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let total = start.elapsed();
        self.results.push(BenchResult {
            name: name.to_string(),
            iters,
            total,
            bytes: bytes_per_iter * iters,
        });
        let r = self.results.last().unwrap();
        println!("{}", r.report_line());
        r
    }

    pub fn print_header(title: &str) {
        println!("\n=== {title} ===");
    }
}

/// Measure a one-shot operation's wall time and throughput.
pub fn measure_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        let mut b = Bench::new().with_budget(Duration::from_millis(20));
        let r = b
            .run("noop-ish", || {
                let mut acc = 0u64;
                for i in 0..100u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc);
                800
            })
            .clone();
        assert!(r.iters >= 1);
        assert!(r.ns_per_iter() > 0.0);
        assert!(r.throughput_mb_s() > 0.0);
    }

    #[test]
    fn measure_once_returns_value() {
        let (v, d) = measure_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn bench_budget_env_override() {
        // Serialized via the env var itself: this is the only test that
        // touches it, and cargo runs tests in one process.
        std::env::set_var("BENCH_BUDGET_MS", "25");
        let b = Bench::new();
        assert_eq!(b.budget(), Duration::from_millis(25));
        assert_eq!(b.warmup, Duration::from_millis(10));
        std::env::set_var("BENCH_BUDGET_MS", "not-a-number");
        assert_eq!(Bench::new().budget(), Bench::DEFAULT_BUDGET);
        std::env::remove_var("BENCH_BUDGET_MS");
        assert_eq!(Bench::new().budget(), Bench::DEFAULT_BUDGET);
    }
}
