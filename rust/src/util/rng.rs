//! Deterministic PRNGs and sampling distributions.
//!
//! All simulation randomness flows through [`Pcg32`], seeded explicitly so
//! every experiment in the paper harness is exactly reproducible. SplitMix64
//! is used for seed expansion (the standard PCG seeding recipe).

/// SplitMix64 — used to expand a single `u64` seed into stream/state pairs.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second output of the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Pcg32 {
    /// Create from a seed; the stream id is derived via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = sm.next_u64();
        let inc = sm.next_u64() | 1;
        let mut rng = Self {
            state,
            inc,
            spare_normal: None,
        };
        rng.next_u32(); // warm up
        rng
    }

    /// Independent child generator (for per-thread / per-partition streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply method; bias negligible for simulation purposes.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (both outputs used: the sine twin
    /// is cached — the cos/ln pair was ~12% of dataset-generation CPU).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (sin, cos) = (std::f64::consts::TAU * u2).sin_cos();
        self.spare_normal = Some(r * sin);
        r * cos
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterized by the *target* mean and sigma of the
    /// underlying normal — used for sparse-feature lengths and job
    /// durations, both of which the paper describes as heavily skewed.
    pub fn lognormal_mean(&mut self, target_mean: f64, sigma: f64) -> f64 {
        // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2) => solve for mu.
        let mu = target_mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-12).ln()
    }

    /// Geometric-ish positive integer with given mean (>= 1).
    pub fn geometric(&mut self, mean: f64) -> u64 {
        (self.exponential((mean - 1.0).max(0.0)).round() as u64) + 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Weighted index sample (weights need not be normalized).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf sampler over ranks `0..n` with exponent `s`.
///
/// Feature popularity and byte reuse in the paper are heavily skewed
/// (Fig 7: ~40% of bytes serve 80% of I/O); Zipf-distributed feature
/// popularity is the standard generative model for that shape.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
    /// Inverse-CDF acceleration table: quantile bucket → first candidate
    /// rank, so sampling is ~O(1) instead of a full binary search (the
    /// dataset generator draws hundreds of millions of ids).
    lut: Vec<u32>,
}

const ZIPF_LUT: usize = 4096;

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        // lut[q] = smallest rank whose cdf ≥ q/ZIPF_LUT.
        let mut lut = Vec::with_capacity(ZIPF_LUT);
        let mut rank = 0usize;
        for q in 0..ZIPF_LUT {
            let target = q as f64 / ZIPF_LUT as f64;
            while rank + 1 < n && cdf[rank] < target {
                rank += 1;
            }
            lut.push(rank as u32);
        }
        Self { cdf, lut }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Probability mass of rank `k` (0-based).
    pub fn pmf(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Sample a rank (0-based); most popular rank is 0.
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        // Jump near the answer via the LUT, then walk forward.
        let bucket = ((u * ZIPF_LUT as f64) as usize).min(ZIPF_LUT - 1);
        let mut i = self.lut[bucket] as usize;
        while self.cdf[i] < u && i + 1 < self.cdf.len() {
            i += 1;
        }
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::new(9);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_hits_target_mean() {
        let mut rng = Pcg32::new(13);
        let n = 200_000;
        let target = 25.97; // RM1 average sparse feature length (Table 5)
        let mean: f64 =
            (0..n).map(|_| rng.lognormal_mean(target, 1.0)).sum::<f64>() / n as f64;
        assert!((mean - target).abs() / target < 0.05, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Pcg32::new(17);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[500].max(1) * 10);
        let total: f64 = (0..1000).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut rng = Pcg32::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg32::new(23);
        let w = [1.0, 0.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[rng.weighted(&w)] += 1;
        }
        assert_eq!(c[1], 0);
        assert!(c[2] > c[0] * 5);
    }
}
