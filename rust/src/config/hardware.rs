//! Hardware specifications: DPP compute nodes (paper Table 10), storage
//! devices (§5.1/§7.1–7.2), and the GPU trainer node (§2/§6).
//!
//! These feed the resource model (`resources`), the storage device model
//! (`tectonic`), and the power model (`power`).

/// A general-purpose compute server class used for DPP Workers (Table 10).
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub name: &'static str,
    pub physical_cores: u32,
    pub nic_gbps: f64,
    pub memory_gb: f64,
    pub peak_mem_bw_gbps: f64, // GB/s
    /// Typical operating power draw (watts) for the power model. Not from
    /// the paper's table; representative single-socket server values.
    pub watts: f64,
}

impl NodeSpec {
    pub fn mem_bw_per_core(&self) -> f64 {
        self.peak_mem_bw_gbps / self.physical_cores as f64
    }

    pub fn nic_bw_per_core(&self) -> f64 {
        self.nic_gbps / self.physical_cores as f64
    }

    pub const fn c_v1() -> NodeSpec {
        NodeSpec {
            name: "C-v1",
            physical_cores: 18,
            nic_gbps: 12.5,
            memory_gb: 64.0,
            peak_mem_bw_gbps: 75.0,
            watts: 300.0,
        }
    }

    pub const fn c_v2() -> NodeSpec {
        NodeSpec {
            name: "C-v2",
            physical_cores: 26,
            nic_gbps: 25.0,
            memory_gb: 64.0,
            peak_mem_bw_gbps: 92.0,
            watts: 350.0,
        }
    }

    pub const fn c_v3() -> NodeSpec {
        NodeSpec {
            name: "C-v3",
            physical_cores: 36,
            nic_gbps: 25.0,
            memory_gb: 64.0,
            peak_mem_bw_gbps: 83.0,
            watts: 400.0,
        }
    }

    pub const fn c_vsota() -> NodeSpec {
        NodeSpec {
            name: "C-vSotA",
            physical_cores: 64,
            nic_gbps: 100.0,
            memory_gb: 1024.0,
            peak_mem_bw_gbps: 205.0,
            watts: 650.0,
        }
    }

    pub fn all_generations() -> Vec<NodeSpec> {
        vec![Self::c_v1(), Self::c_v2(), Self::c_v3(), Self::c_vsota()]
    }
}

/// Storage medium behaviour model. The paper's storage findings hinge on
/// HDD seek behaviour under small I/O (Table 6 + Table 12: feature
/// flattening cut storage throughput 97% before coalesced reads).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MediaKind {
    Hdd,
    Ssd,
}

#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub kind: MediaKind,
    pub name: &'static str,
    /// Average positioning time per random I/O (seek + rotational latency).
    pub seek_ms: f64,
    /// Sequential transfer rate, MB/s.
    pub transfer_mbps: f64,
    /// Capacity in TB.
    pub capacity_tb: f64,
    /// Operating power, watts.
    pub watts: f64,
}

impl DeviceSpec {
    /// A nearline datacenter HDD.
    pub const fn hdd() -> DeviceSpec {
        DeviceSpec {
            kind: MediaKind::Hdd,
            name: "HDD-nearline",
            seek_ms: 8.0,
            transfer_mbps: 180.0,
            capacity_tb: 14.0,
            watts: 8.0,
        }
    }

    /// A datacenter NVMe SSD.
    pub const fn ssd() -> DeviceSpec {
        DeviceSpec {
            kind: MediaKind::Ssd,
            name: "SSD-nvme",
            seek_ms: 0.02,
            transfer_mbps: 2800.0,
            capacity_tb: 4.0,
            watts: 12.0,
        }
    }

    /// Max random 4K IOPS implied by the seek model.
    pub fn max_iops_4k(&self) -> f64 {
        let per_io_s = self.seek_ms / 1e3 + 4096.0 / (self.transfer_mbps * 1e6);
        1.0 / per_io_s
    }

    pub fn iops_per_watt(&self) -> f64 {
        self.max_iops_4k() / self.watts
    }

    pub fn capacity_per_watt_tb(&self) -> f64 {
        self.capacity_tb / self.watts
    }

    /// Service time (seconds) for one I/O of `len` bytes; `sequential`
    /// suppresses the positioning cost (head already in place).
    pub fn service_time(&self, len: u64, sequential: bool) -> f64 {
        let pos = if sequential { 0.0 } else { self.seek_ms / 1e3 };
        pos + len as f64 / (self.transfer_mbps * 1e6)
    }
}

/// ZionEX-like GPU training node (§2): 8 GPUs, 4 CPU sockets, 4×100G
/// frontend NICs (the paper's V100 testbed in §6.2 uses 2 sockets +
/// 2×100G; we model both).
#[derive(Clone, Debug)]
pub struct TrainerNodeSpec {
    pub name: &'static str,
    pub gpus: u32,
    pub cpu_sockets: u32,
    pub cores_per_socket: u32,
    pub frontend_nic_gbps: f64, // aggregate
    pub peak_mem_bw_gbps: f64,  // aggregate host memory bandwidth
    pub gpu_watts: f64,         // per GPU
    pub host_watts: f64,
}

impl TrainerNodeSpec {
    /// The §6.2 experiment node: 2×28-core sockets, 2×100G, 8 V100s.
    pub const fn v100_node() -> TrainerNodeSpec {
        TrainerNodeSpec {
            name: "trainer-v100",
            gpus: 8,
            cpu_sockets: 2,
            cores_per_socket: 28,
            frontend_nic_gbps: 200.0,
            peak_mem_bw_gbps: 256.0,
            gpu_watts: 300.0,
            host_watts: 700.0,
        }
    }

    /// ZionEX: 8 A100s, 4 sockets, 4×100G frontend.
    pub const fn zionex() -> TrainerNodeSpec {
        TrainerNodeSpec {
            name: "ZionEX",
            gpus: 8,
            cpu_sockets: 4,
            cores_per_socket: 28,
            frontend_nic_gbps: 400.0,
            peak_mem_bw_gbps: 512.0,
            gpu_watts: 400.0,
            host_watts: 900.0,
        }
    }

    pub fn total_cores(&self) -> u32 {
        self.cpu_sockets * self.cores_per_socket
    }

    pub fn total_watts(&self) -> f64 {
        self.gpus as f64 * self.gpu_watts + self.host_watts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table10_specs_match_paper() {
        let v1 = NodeSpec::c_v1();
        assert_eq!(v1.physical_cores, 18);
        assert_eq!(v1.nic_gbps, 12.5);
        assert_eq!(v1.peak_mem_bw_gbps, 75.0);
        // Derived columns (paper: 4.2 GB/s/core, 0.69 Gbps/core).
        assert!((v1.mem_bw_per_core() - 4.2).abs() < 0.1);
        assert!((v1.nic_bw_per_core() - 0.69).abs() < 0.01);
        let sota = NodeSpec::c_vsota();
        assert!((sota.mem_bw_per_core() - 3.2).abs() < 0.1);
        assert!((sota.nic_bw_per_core() - 1.56).abs() < 0.01);
    }

    #[test]
    fn membw_per_core_declines_across_generations() {
        // §6.3's core claim: per-core memory bandwidth shrinks relative to
        // per-core NIC bandwidth across C-v1 → C-v3.
        let v1 = NodeSpec::c_v1();
        let v3 = NodeSpec::c_v3();
        assert!(v3.mem_bw_per_core() < v1.mem_bw_per_core());
    }

    #[test]
    fn ssd_iops_per_watt_dominates_capacity_per_watt() {
        // §7.2: SSD ≈326% IOPS/W but only ≈9% capacity/W vs HDD.
        let hdd = DeviceSpec::hdd();
        let ssd = DeviceSpec::ssd();
        let iops_ratio = ssd.iops_per_watt() / hdd.iops_per_watt();
        let cap_ratio = ssd.capacity_per_watt_tb() / hdd.capacity_per_watt_tb();
        assert!(iops_ratio > 3.0, "iops ratio {iops_ratio}");
        assert!(cap_ratio < 0.5, "cap ratio {cap_ratio}");
    }

    #[test]
    fn hdd_service_time_is_seek_dominated_for_small_io() {
        let hdd = DeviceSpec::hdd();
        // A 20 KB random read (Table 6 median-ish) is dominated by seek.
        let t = hdd.service_time(20_000, false);
        let seek = hdd.seek_ms / 1e3;
        assert!(seek / t > 0.95);
        // An 8 MB sequential read is transfer dominated.
        let t = hdd.service_time(8 << 20, true);
        assert!(t > 0.04);
    }
}
