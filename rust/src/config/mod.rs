//! Model (RM1/RM2/RM3) and pipeline configuration.
//!
//! The per-model constants come straight from the paper's characterization
//! tables (Tables 3–9); the dataset generator and trainer demand model are
//! parameterized by them, and the experiment drivers print these as the
//! "paper" column next to what the simulation measured.

pub mod hardware;

pub use hardware::*;

/// Which production recommendation model a workload models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RmId {
    Rm1,
    Rm2,
    Rm3,
}

impl RmId {
    pub const ALL: [RmId; 3] = [RmId::Rm1, RmId::Rm2, RmId::Rm3];

    pub fn name(&self) -> &'static str {
        match self {
            RmId::Rm1 => "RM1",
            RmId::Rm2 => "RM2",
            RmId::Rm3 => "RM3",
        }
    }

    pub fn index(&self) -> usize {
        match self {
            RmId::Rm1 => 0,
            RmId::Rm2 => 1,
            RmId::Rm3 => 2,
        }
    }
}

/// Per-model characterization constants from the paper.
#[derive(Clone, Debug)]
pub struct RmConfig {
    pub id: RmId,

    // ---- Table 5: dataset (what is *logged* in the table) ----
    /// # float (dense) features logged in the dataset.
    pub dataset_dense_features: usize,
    /// # sparse features logged in the dataset.
    pub dataset_sparse_features: usize,
    /// Average fraction of samples that log a given feature.
    pub avg_coverage: f64,
    /// Average sparse feature list length.
    pub avg_sparse_len: f64,
    /// Paper: % of logged features a training job reads.
    pub paper_pct_feats_used: f64,
    /// Paper: % of stored bytes a training job reads.
    pub paper_pct_bytes_used: f64,

    // ---- Table 4: what a representative RC model *uses* ----
    pub used_dense_features: usize,
    pub used_sparse_features: usize,
    pub derived_features: usize,

    // ---- Table 3: partition sizing (PB, compressed) ----
    pub all_partitions_pb: f64,
    pub each_partition_pb: f64,
    pub used_partitions_pb: f64,

    // ---- Table 8: trainer demand ----
    /// GB/s of preprocessed tensors per 8-GPU training node.
    pub trainer_node_gbps: f64,

    // ---- Table 9: DPP worker characterization (paper reference) ----
    pub paper_worker_kqps: f64,
    pub paper_storage_rx_gbps: f64,
    pub paper_transform_rx_gbps: f64,
    pub paper_transform_tx_gbps: f64,
    pub paper_workers_per_trainer: f64,

    // ---- Fig 9: transform mix (fraction of transform cycles) ----
    pub xform_feature_gen_frac: f64,
    pub xform_sparse_norm_frac: f64,
    pub xform_dense_norm_frac: f64,

    // ---- Fig 7: reuse (paper: % of bytes serving 80% of I/O) ----
    pub paper_bytes_for_80pct_io: f64,
    /// Zipf skew of feature popularity across jobs; calibrated so the
    /// popularity CDF reproduces `paper_bytes_for_80pct_io`.
    pub popularity_zipf_s: f64,

    /// Relative preprocessing compute intensity (RM1 has expensive
    /// feature-generation-heavy transforms; RM3 is light per sample but
    /// demands many more samples/s).
    pub transform_intensity: f64,
}

impl RmConfig {
    pub fn get(id: RmId) -> RmConfig {
        match id {
            RmId::Rm1 => RmConfig {
                id,
                dataset_dense_features: 12115,
                dataset_sparse_features: 1763,
                avg_coverage: 0.45,
                avg_sparse_len: 25.97,
                paper_pct_feats_used: 11.0,
                paper_pct_bytes_used: 37.0,
                used_dense_features: 1221,
                used_sparse_features: 298,
                derived_features: 304,
                all_partitions_pb: 13.45,
                each_partition_pb: 0.15,
                used_partitions_pb: 11.95,
                trainer_node_gbps: 16.50,
                paper_worker_kqps: 11.623,
                paper_storage_rx_gbps: 0.8,
                paper_transform_rx_gbps: 1.37,
                paper_transform_tx_gbps: 0.68,
                paper_workers_per_trainer: 24.16,
                xform_feature_gen_frac: 0.80,
                xform_sparse_norm_frac: 0.15,
                xform_dense_norm_frac: 0.05,
                paper_bytes_for_80pct_io: 0.39,
                popularity_zipf_s: 0.85,
                transform_intensity: 1.9,
            },
            RmId::Rm2 => RmConfig {
                id,
                dataset_dense_features: 12596,
                dataset_sparse_features: 1817,
                avg_coverage: 0.41,
                avg_sparse_len: 25.57,
                paper_pct_feats_used: 10.0,
                paper_pct_bytes_used: 34.0,
                used_dense_features: 1113,
                used_sparse_features: 306,
                derived_features: 317,
                all_partitions_pb: 29.18,
                each_partition_pb: 0.32,
                used_partitions_pb: 25.94,
                trainer_node_gbps: 4.69,
                paper_worker_kqps: 7.995,
                paper_storage_rx_gbps: 1.2,
                paper_transform_rx_gbps: 0.96,
                paper_transform_tx_gbps: 0.50,
                paper_workers_per_trainer: 9.44,
                xform_feature_gen_frac: 0.75,
                xform_sparse_norm_frac: 0.20,
                xform_dense_norm_frac: 0.05,
                paper_bytes_for_80pct_io: 0.37,
                popularity_zipf_s: 0.80,
                transform_intensity: 1.0,
            },
            RmId::Rm3 => RmConfig {
                id,
                dataset_dense_features: 5707,
                dataset_sparse_features: 188,
                avg_coverage: 0.29,
                avg_sparse_len: 19.64,
                paper_pct_feats_used: 9.0,
                paper_pct_bytes_used: 21.0,
                used_dense_features: 504,
                used_sparse_features: 42,
                derived_features: 1,
                all_partitions_pb: 2.93,
                each_partition_pb: 0.07,
                used_partitions_pb: 1.95,
                trainer_node_gbps: 12.00,
                paper_worker_kqps: 36.921,
                paper_storage_rx_gbps: 0.8,
                paper_transform_rx_gbps: 1.01,
                paper_transform_tx_gbps: 0.22,
                paper_workers_per_trainer: 55.22,
                xform_feature_gen_frac: 0.55,
                xform_sparse_norm_frac: 0.25,
                xform_dense_norm_frac: 0.20,
                paper_bytes_for_80pct_io: 0.18,
                popularity_zipf_s: 1.35,
                transform_intensity: 0.35,
            },
        }
    }

    pub fn all() -> Vec<RmConfig> {
        RmId::ALL.iter().map(|&id| RmConfig::get(id)).collect()
    }

    /// Total features logged in the dataset.
    pub fn dataset_features(&self) -> usize {
        self.dataset_dense_features + self.dataset_sparse_features
    }

    /// Total features read by a representative training job.
    pub fn used_features(&self) -> usize {
        self.used_dense_features + self.used_sparse_features
    }

    /// Fraction of logged features a job reads (compare Table 5 "% Feats").
    pub fn frac_feats_used(&self) -> f64 {
        self.used_features() as f64 / self.dataset_features() as f64
    }
}

/// Scale factor between our in-memory simulation and the fleet numbers the
/// paper reports. We generate datasets at MiB scale; capacities and power
/// are presented at fleet scale by multiplying by `bytes_scale`.
#[derive(Clone, Copy, Debug)]
pub struct SimScale {
    /// Simulated rows per table partition.
    pub rows_per_partition: usize,
    /// How many logged features to actually materialize (full feature count
    /// is used for sizing math; materialized subset for byte-level realism).
    pub materialized_features: usize,
    /// Number of partitions generated per table.
    pub partitions: usize,
}

impl SimScale {
    /// Small scale for unit tests.
    pub fn tiny() -> SimScale {
        SimScale {
            rows_per_partition: 64,
            materialized_features: 48,
            partitions: 2,
        }
    }

    /// Default scale for experiments (fast but statistically meaningful).
    pub fn standard() -> SimScale {
        SimScale {
            rows_per_partition: 2048,
            materialized_features: 256,
            partitions: 4,
        }
    }

    /// Larger scale for benchmarks.
    pub fn bench() -> SimScale {
        SimScale {
            rows_per_partition: 8192,
            materialized_features: 512,
            partitions: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_values_match_paper() {
        let rm1 = RmConfig::get(RmId::Rm1);
        assert_eq!(rm1.used_dense_features, 1221);
        assert_eq!(rm1.used_sparse_features, 298);
        assert_eq!(rm1.derived_features, 304);
        let rm3 = RmConfig::get(RmId::Rm3);
        assert_eq!(rm3.derived_features, 1);
    }

    #[test]
    fn frac_feats_used_matches_table5() {
        // Paper: 11 / 10 / 9 percent.
        for (id, expect) in [(RmId::Rm1, 11.0), (RmId::Rm2, 10.0), (RmId::Rm3, 9.0)] {
            let c = RmConfig::get(id);
            let pct = c.frac_feats_used() * 100.0;
            assert!(
                (pct - expect).abs() < 1.5,
                "{}: computed {pct:.1}% vs paper {expect}%",
                c.id.name()
            );
        }
    }

    #[test]
    fn trainer_demand_spread_is_6x() {
        // Paper §6.1: GPU throughput varies by over ~3.5x across models
        // (16.5 / 4.69). Guard the ratio.
        let hi = RmConfig::get(RmId::Rm1).trainer_node_gbps;
        let lo = RmConfig::get(RmId::Rm2).trainer_node_gbps;
        assert!(hi / lo > 3.0);
    }

    #[test]
    fn transform_mix_sums_to_one() {
        for c in RmConfig::all() {
            let s = c.xform_feature_gen_frac
                + c.xform_sparse_norm_frac
                + c.xform_dense_norm_frac;
            assert!((s - 1.0).abs() < 1e-9, "{}", c.id.name());
        }
    }
}
