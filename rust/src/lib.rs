//! `dsi` — a full-system reproduction of Meta's **Data Storage and
//! Ingestion (DSI) pipeline** for large-scale deep recommendation model
//! training (Zhao et al., ISCA '22).
//!
//! The crate implements, from scratch:
//!
//! * the **DWRF** columnar warehouse format with feature flattening,
//!   coalesced reads, feature reordering, and large stripes ([`dwrf`]);
//! * a **Tectonic**-like distributed append-only filesystem over modelled
//!   HDD/SSD storage nodes ([`tectonic`]);
//! * **Scribe**/ETL offline data generation ([`scribe`], [`etl`],
//!   [`datagen`]) into a Hive-like partitioned warehouse ([`warehouse`]);
//! * the 16 production preprocessing transforms and their per-feature
//!   DAGs ([`transforms`]);
//! * **DPP**, the disaggregated online preprocessing service — Master,
//!   Workers, Clients, autoscaler ([`dpp`]);
//! * trainer, node-resource, and power models ([`trainer`], [`resources`],
//!   [`power`]);
//! * the global multi-region training-job scheduler ([`sched`]) and
//!   byte/feature popularity tracking ([`popularity`]);
//! * RecD-style **end-to-end sample deduplication** ([`dedup`]):
//!   content-addressed payload fingerprints and duplicate-run detection
//!   over warehouse sessions, a DedupDWRF encoding that clusters
//!   duplicate sessions into stripes and stores each unique feature
//!   payload once (plus an inverse index), and a dedup-aware DPP path
//!   that preprocesses each unique payload once and expands batches on
//!   the Client — cutting storage, read I/O, and preprocessing together;
//! * **predicate pushdown** ([`filter`]): session row predicates
//!   (timestamp recency, negative downsampling, feature presence,
//!   deterministic sampling) flow from the spec down to physical I/O —
//!   DWRF footers carry per-stripe statistics that let the planner and
//!   the DPP Master skip provably-empty stripes before any byte is
//!   fetched, and partially-matching stripes decode once into
//!   selection-vector batches so transforms touch only surviving rows;
//! * **cross-job shared reads** ([`broker`]): a ReadBroker between
//!   Master plans and the cluster — concurrent sessions register their
//!   planned (file, stripe) interest, and each popular stripe is fetched
//!   and decoded once into a ref-counted, budget-bounded buffer, with
//!   per-session predicates, selection vectors, and transforms applied
//!   after the shared decode (outputs stay byte-identical to private
//!   scans);
//! * **end-to-end observability** ([`obs`]): per-stage latency
//!   histograms, span tracing exportable as Chrome trace-event JSON
//!   (Perfetto-loadable), periodic session telemetry time-series, and
//!   client data-stall attribution (storage- / decode- /
//!   transform-bound / worker-starved) feeding the autoscaler;
//! * a PJRT runtime that executes the AOT-compiled JAX/Pallas DLRM
//!   artifacts from the Rust hot path ([`runtime`]);
//! * drivers that regenerate every table and figure of the paper
//!   ([`paper`]).

pub mod broker;
pub mod config;
pub mod data;
pub mod datagen;
pub mod dedup;
pub mod dpp;
pub mod dwrf;
pub mod etl;
pub mod filter;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod paper;
pub mod popularity;
pub mod power;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod schema;
pub mod scribe;
pub mod sync;
pub mod tectonic;
pub mod trainer;
pub mod transforms;
pub mod util;
pub mod warehouse;
