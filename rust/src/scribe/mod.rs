//! Scribe — Meta's distributed messaging system (§3.1.1), modelled as
//! named append-only record streams (the LogDevice layer is abstracted to
//! in-memory storage; stream semantics — ordered, trimmable, grouped by
//! logical stream — are preserved).
//!
//! The model-serving simulator publishes raw *feature logs* and *event
//! logs* here at serving time (features logged at serving time to avoid
//! data leakage, §3.1.1); the ETL engine tails the streams and joins them
//! into labeled samples.

use crate::sync::{read_or_recover, write_or_recover, RwLock};
use std::collections::HashMap;

/// A raw feature log: everything the model-serving framework computed for
/// one (user, item) evaluation.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureLog {
    pub request_id: u64,
    pub timestamp: u64,
    pub dense: Vec<(u32, f32)>,
    pub sparse: Vec<(u32, Vec<u64>)>,
    pub scored: Vec<(u32, Vec<(u64, f32)>)>,
}

/// An event log: the monitored outcome of one recommendation.
#[derive(Clone, Debug, PartialEq)]
pub struct EventLog {
    pub request_id: u64,
    pub timestamp: u64,
    /// Did the user interact (click/like/...)?
    pub engaged: bool,
}

/// A record in a Scribe stream.
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    Feature(FeatureLog),
    Event(EventLog),
}

/// The Scribe service: named streams of records.
#[derive(Default)]
pub struct Scribe {
    streams: RwLock<HashMap<String, Vec<Record>>>,
}

impl Scribe {
    pub fn new() -> Scribe {
        Scribe::default()
    }

    pub fn publish(&self, stream: &str, rec: Record) {
        write_or_recover(&self.streams, "scribe streams")
            .entry(stream.to_string())
            .or_default()
            .push(rec);
    }

    pub fn publish_all(&self, stream: &str, recs: impl IntoIterator<Item = Record>) {
        let mut s = write_or_recover(&self.streams, "scribe streams");
        s.entry(stream.to_string()).or_default().extend(recs);
    }

    /// Read records `[from, ..)` of a stream; returns the next cursor.
    pub fn tail(&self, stream: &str, from: usize) -> (Vec<Record>, usize) {
        let s = read_or_recover(&self.streams, "scribe streams");
        match s.get(stream) {
            Some(recs) if from < recs.len() => (recs[from..].to_vec(), recs.len()),
            Some(recs) => (Vec::new(), recs.len()),
            None => (Vec::new(), from),
        }
    }

    pub fn len(&self, stream: &str) -> usize {
        read_or_recover(&self.streams, "scribe streams")
            .get(stream)
            .map_or(0, |r| r.len())
    }

    pub fn is_empty(&self, stream: &str) -> bool {
        self.len(stream) == 0
    }

    /// Trim a prefix (LogDevice streams are trimmable).
    pub fn trim(&self, stream: &str, upto: usize) {
        if let Some(recs) =
            write_or_recover(&self.streams, "scribe streams").get_mut(stream)
        {
            let upto = upto.min(recs.len());
            recs.drain(..upto);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat(id: u64) -> Record {
        Record::Feature(FeatureLog {
            request_id: id,
            timestamp: id * 10,
            dense: vec![(0, 1.0)],
            sparse: vec![],
            scored: vec![],
        })
    }

    #[test]
    fn publish_tail_roundtrip() {
        let s = Scribe::new();
        s.publish("rm1_features", feat(1));
        s.publish("rm1_features", feat(2));
        let (recs, cur) = s.tail("rm1_features", 0);
        assert_eq!(recs.len(), 2);
        assert_eq!(cur, 2);
        let (recs, cur) = s.tail("rm1_features", cur);
        assert!(recs.is_empty());
        assert_eq!(cur, 2);
    }

    #[test]
    fn streams_are_independent() {
        let s = Scribe::new();
        s.publish("a", feat(1));
        assert_eq!(s.len("a"), 1);
        assert_eq!(s.len("b"), 0);
        assert!(s.is_empty("b"));
    }

    #[test]
    fn trim_drops_prefix() {
        let s = Scribe::new();
        s.publish_all("a", (0..10).map(feat));
        s.trim("a", 4);
        assert_eq!(s.len("a"), 6);
        let (recs, _) = s.tail("a", 0);
        match &recs[0] {
            Record::Feature(f) => assert_eq!(f.request_id, 4),
            _ => panic!("wrong record"),
        }
    }

    #[test]
    fn tail_unknown_stream_is_empty() {
        let s = Scribe::new();
        let (recs, cur) = s.tail("missing", 5);
        assert!(recs.is_empty());
        assert_eq!(cur, 5);
    }
}
