//! `cfg(loom)` instrumented primitives.
//!
//! Same surface as the `std::sync` types re-exported by
//! [`super`](crate::sync), but every acquire, atomic op, and unlock is
//! a scheduling point for [`model`](super::model). Outside an active
//! model iteration (`model::in_model() == false`) every operation
//! delegates to the real blocking `std` primitive, so the full normal
//! test suite still runs correctly in a `--cfg loom` build.

use super::model;
use std::ops::{Deref, DerefMut};
use std::sync::{LockResult, PoisonError, TryLockError};

// ---------------------------------------------------------------- Mutex

pub struct Mutex<T: ?Sized> {
    inner: std::sync::Mutex<T>,
}

impl<T> Mutex<T> {
    pub fn new(t: T) -> Mutex<T> {
        Mutex {
            inner: std::sync::Mutex::new(t),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T: ?Sized> Mutex<T> {
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if !model::in_model() {
            return match self.inner.lock() {
                Ok(g) => Ok(MutexGuard {
                    lock: self,
                    inner: Some(g),
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                })),
            };
        }
        model::yield_point();
        loop {
            match self.inner.try_lock() {
                Ok(g) => {
                    return Ok(MutexGuard {
                        lock: self,
                        inner: Some(g),
                    })
                }
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(MutexGuard {
                        lock: self,
                        inner: Some(p.into_inner()),
                    }))
                }
                Err(TryLockError::WouldBlock) => model::yield_blocked(),
            }
        }
    }
}

pub struct MutexGuard<'a, T: ?Sized> {
    lock: &'a Mutex<T>,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T: ?Sized> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        let released = self.inner.take().is_some();
        // Unlock is a scheduling point: a freshly-released lock is
        // exactly where a peer should get a chance to run. Skip while
        // unwinding so a failed model assertion cannot double-panic.
        if released && model::in_model() && !std::thread::panicking() {
            model::yield_point();
        }
    }
}

// -------------------------------------------------------------- Condvar

pub struct Condvar {
    inner: std::sync::Condvar,
    /// Notification epoch: model-mode waiters spin until it changes.
    /// Snapshots are taken while holding the waited-on mutex, and the
    /// single-token scheduler totally orders the snapshot against any
    /// notify, so a wakeup can never be lost (spurious wakeups are
    /// possible and allowed, exactly as with `std::sync::Condvar`).
    gen: std::sync::atomic::AtomicU64,
}

impl Condvar {
    pub fn new() -> Condvar {
        Condvar {
            inner: std::sync::Condvar::new(),
            gen: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn wait<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
    ) -> LockResult<MutexGuard<'a, T>> {
        let lock = guard.lock;
        if !model::in_model() {
            let std_guard = guard.inner.take().expect("guard taken");
            drop(guard); // inert: inner already taken
            return match self.inner.wait(std_guard) {
                Ok(g) => Ok(MutexGuard {
                    lock,
                    inner: Some(g),
                }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock,
                    inner: Some(p.into_inner()),
                })),
            };
        }
        use std::sync::atomic::Ordering;
        // Snapshot while still holding the lock, then release it.
        let seen = self.gen.load(Ordering::SeqCst);
        drop(guard);
        while self.gen.load(Ordering::SeqCst) == seen {
            model::yield_blocked();
        }
        lock.lock()
    }

    pub fn notify_all(&self) {
        if model::in_model() {
            self.gen.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }
        self.inner.notify_all();
    }

    pub fn notify_one(&self) {
        if model::in_model() {
            // Model mode wakes every spinner (spurious wakeups are
            // permitted by the condvar contract).
            self.gen.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }
        self.inner.notify_one();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl std::fmt::Debug for Condvar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// --------------------------------------------------------------- RwLock

pub struct RwLock<T: ?Sized> {
    inner: std::sync::RwLock<T>,
}

impl<T> RwLock<T> {
    pub fn new(t: T) -> RwLock<T> {
        RwLock {
            inner: std::sync::RwLock::new(t),
        }
    }
}

impl<T: Default> Default for RwLock<T> {
    fn default() -> RwLock<T> {
        RwLock::new(T::default())
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for RwLock<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.inner.fmt(f)
    }
}

impl<T: ?Sized> RwLock<T> {
    pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
        if !model::in_model() {
            return match self.inner.read() {
                Ok(g) => Ok(RwLockReadGuard { inner: Some(g) }),
                Err(p) => Err(PoisonError::new(RwLockReadGuard {
                    inner: Some(p.into_inner()),
                })),
            };
        }
        model::yield_point();
        loop {
            match self.inner.try_read() {
                Ok(g) => return Ok(RwLockReadGuard { inner: Some(g) }),
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(RwLockReadGuard {
                        inner: Some(p.into_inner()),
                    }))
                }
                Err(TryLockError::WouldBlock) => model::yield_blocked(),
            }
        }
    }

    pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
        if !model::in_model() {
            return match self.inner.write() {
                Ok(g) => Ok(RwLockWriteGuard { inner: Some(g) }),
                Err(p) => Err(PoisonError::new(RwLockWriteGuard {
                    inner: Some(p.into_inner()),
                })),
            };
        }
        model::yield_point();
        loop {
            match self.inner.try_write() {
                Ok(g) => return Ok(RwLockWriteGuard { inner: Some(g) }),
                Err(TryLockError::Poisoned(p)) => {
                    return Err(PoisonError::new(RwLockWriteGuard {
                        inner: Some(p.into_inner()),
                    }))
                }
                Err(TryLockError::WouldBlock) => model::yield_blocked(),
            }
        }
    }
}

pub struct RwLockReadGuard<'a, T: ?Sized> {
    inner: Option<std::sync::RwLockReadGuard<'a, T>>,
}

impl<T: ?Sized> Deref for RwLockReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized> Drop for RwLockReadGuard<'_, T> {
    fn drop(&mut self) {
        let released = self.inner.take().is_some();
        if released && model::in_model() && !std::thread::panicking() {
            model::yield_point();
        }
    }
}

pub struct RwLockWriteGuard<'a, T: ?Sized> {
    inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
}

impl<T: ?Sized> Deref for RwLockWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard taken")
    }
}

impl<T: ?Sized> DerefMut for RwLockWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard taken")
    }
}

impl<T: ?Sized> Drop for RwLockWriteGuard<'_, T> {
    fn drop(&mut self) {
        let released = self.inner.take().is_some();
        if released && model::in_model() && !std::thread::panicking() {
            model::yield_point();
        }
    }
}

// -------------------------------------------------------------- Atomics

/// Instrumented atomics: each op is a scheduling point, then delegates
/// to the real `std` atomic (interleavings are explored at sequential
/// consistency regardless of the ordering argument).
pub mod atomic {
    use super::model;
    pub use std::sync::atomic::Ordering;

    macro_rules! shim_atomic {
        ($name:ident, $std:ty, $prim:ty) => {
            #[derive(Debug, Default)]
            pub struct $name(pub(crate) $std);

            impl $name {
                pub fn new(v: $prim) -> $name {
                    $name(<$std>::new(v))
                }

                pub fn load(&self, o: Ordering) -> $prim {
                    model::yield_point();
                    self.0.load(o)
                }

                pub fn store(&self, v: $prim, o: Ordering) {
                    model::yield_point();
                    self.0.store(v, o)
                }

                pub fn swap(&self, v: $prim, o: Ordering) -> $prim {
                    model::yield_point();
                    self.0.swap(v, o)
                }

                pub fn fetch_add(&self, v: $prim, o: Ordering) -> $prim {
                    model::yield_point();
                    self.0.fetch_add(v, o)
                }

                pub fn fetch_sub(&self, v: $prim, o: Ordering) -> $prim {
                    model::yield_point();
                    self.0.fetch_sub(v, o)
                }

                pub fn compare_exchange(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    model::yield_point();
                    self.0.compare_exchange(cur, new, ok, err)
                }

                pub fn compare_exchange_weak(
                    &self,
                    cur: $prim,
                    new: $prim,
                    ok: Ordering,
                    err: Ordering,
                ) -> Result<$prim, $prim> {
                    model::yield_point();
                    // The strong variant underneath: the model explores
                    // interleavings, not spurious CAS failures.
                    self.0.compare_exchange(cur, new, ok, err)
                }
            }
        };
    }

    shim_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    shim_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

    #[derive(Debug, Default)]
    pub struct AtomicBool(std::sync::atomic::AtomicBool);

    impl AtomicBool {
        pub fn new(v: bool) -> AtomicBool {
            AtomicBool(std::sync::atomic::AtomicBool::new(v))
        }

        pub fn load(&self, o: Ordering) -> bool {
            model::yield_point();
            self.0.load(o)
        }

        pub fn store(&self, v: bool, o: Ordering) {
            model::yield_point();
            self.0.store(v, o)
        }

        pub fn swap(&self, v: bool, o: Ordering) -> bool {
            model::yield_point();
            self.0.swap(v, o)
        }
    }
}
