//! Deterministic bounded-preemption interleaving checker (loom-lite).
//!
//! [`check`] runs a closure many times; each iteration a single
//! *execution token* is passed between the test thread and the threads
//! it spawns via [`thread::spawn`]. Only the token holder runs. Every
//! instrumented operation in [`super::shim`] calls [`yield_point`],
//! where the scheduler may preempt (hand the token to a random peer,
//! consuming one unit of a bounded preemption budget) — the classic
//! bounded-preemption heuristic: almost all real concurrency bugs
//! manifest within a handful of forced context switches. Blocked
//! operations (contended `try_lock`, condvar spins) call
//! [`yield_blocked`], which always hands the token over without
//! consuming budget.
//!
//! Seeds are derived deterministically from the model name and
//! iteration index, so a failure reproduces exactly. A step cap turns
//! deadlocks and livelocks into panics instead of hangs.
//!
//! Knobs: `DSI_LOOM_ITERS` (iterations per model, default 128) and
//! `DSI_LOOM_PREEMPTIONS` (budget per iteration, default 8).

use crate::util::rng::Pcg32;
use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as StdCondvar, Mutex as StdMutex};

/// Hard per-iteration bound on scheduling points: a model that spins
/// this long is deadlocked or livelocked.
const STEP_CAP: u64 = 200_000;

thread_local! {
    static CTX: RefCell<Option<(Arc<Scheduler>, usize)>> =
        const { RefCell::new(None) };
}

struct SchedState {
    /// Thread id currently holding the execution token.
    current: usize,
    finished: Vec<bool>,
    rng: Pcg32,
    preemptions_left: u32,
    steps: u64,
    failed: bool,
}

impl SchedState {
    fn runnable_peers(&self, me: usize) -> Vec<usize> {
        (0..self.finished.len())
            .filter(|&i| i != me && !self.finished[i])
            .collect()
    }
}

pub struct Scheduler {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
}

impl Scheduler {
    /// The scheduler's own lock must keep working while a model thread
    /// unwinds from a failed assertion.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn fail_and_panic(
        &self,
        mut st: std::sync::MutexGuard<'_, SchedState>,
        msg: &str,
    ) -> ! {
        st.failed = true;
        self.cv.notify_all();
        drop(st);
        panic!("{msg}");
    }

    /// One scheduling point for thread `me`. `blocked` means the caller
    /// cannot make progress until some other thread runs.
    fn switch(&self, me: usize, blocked: bool) {
        let mut st = self.lock_state();
        if st.failed {
            drop(st);
            panic!("model iteration failed in another thread");
        }
        st.steps += 1;
        if st.steps > STEP_CAP {
            self.fail_and_panic(
                st,
                "model step cap exceeded (deadlock or livelock?)",
            );
        }
        let peers = st.runnable_peers(me);
        if blocked {
            if peers.is_empty() {
                self.fail_and_panic(
                    st,
                    "model deadlock: blocked with no runnable peers",
                );
            }
            let pick = peers[st.rng.below(peers.len() as u64) as usize];
            st.current = pick;
            self.cv.notify_all();
        } else if !peers.is_empty()
            && st.preemptions_left > 0
            && st.rng.chance(0.4)
        {
            st.preemptions_left -= 1;
            let pick = peers[st.rng.below(peers.len() as u64) as usize];
            st.current = pick;
            self.cv.notify_all();
        } else {
            return; // keep the token
        }
        while st.current != me && !st.failed {
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(|p| p.into_inner());
        }
        if st.failed {
            drop(st);
            panic!("model iteration failed in another thread");
        }
    }
}

fn current_ctx() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// True inside an active model iteration on this thread.
pub fn in_model() -> bool {
    CTX.with(|c| c.borrow().is_some())
}

/// Voluntary scheduling point; no-op outside a model iteration.
pub fn yield_point() {
    if let Some((sched, me)) = current_ctx() {
        sched.switch(me, false);
    }
}

/// Mandatory hand-off: the caller is blocked until a peer runs.
pub fn yield_blocked() {
    if let Some((sched, me)) = current_ctx() {
        sched.switch(me, true);
    }
}

/// Marks a model thread finished (even on unwind) and passes the token
/// on so the remaining threads keep running.
struct FinishGuard {
    sched: Arc<Scheduler>,
    id: usize,
}

impl Drop for FinishGuard {
    fn drop(&mut self) {
        let mut st = self.sched.lock_state();
        st.finished[self.id] = true;
        if std::thread::panicking() {
            st.failed = true;
        }
        let peers = st.runnable_peers(self.id);
        if !peers.is_empty() {
            let pick = peers[st.rng.below(peers.len() as u64) as usize];
            st.current = pick;
        }
        drop(st);
        self.sched.cv.notify_all();
    }
}

/// Model-aware threads: spawned threads join the token-passing protocol
/// of the current [`check`] iteration.
pub mod thread {
    use super::*;

    pub struct JoinHandle<T> {
        id: usize,
        sched: Arc<Scheduler>,
        inner: std::thread::JoinHandle<T>,
    }

    impl<T> JoinHandle<T> {
        /// Spin (yielding the token) until the target thread finishes,
        /// then reap it.
        pub fn join(self) -> std::thread::Result<T> {
            loop {
                {
                    let st = self.sched.lock_state();
                    if st.finished[self.id] {
                        break;
                    }
                }
                super::yield_blocked();
            }
            self.inner.join()
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (sched, _me) = current_ctx()
            .expect("model::thread::spawn outside model::check");
        // Register while holding the token: the id is fixed before any
        // peer can observe the new thread.
        let id = {
            let mut st = sched.lock_state();
            st.finished.push(false);
            st.finished.len() - 1
        };
        let child_sched = sched.clone();
        let inner = std::thread::spawn(move || {
            CTX.with(|c| {
                *c.borrow_mut() = Some((child_sched.clone(), id))
            });
            let _finish = FinishGuard {
                sched: child_sched.clone(),
                id,
            };
            // Wait for the token before touching shared state.
            {
                let mut st = child_sched.lock_state();
                while st.current != id && !st.failed {
                    st = child_sched
                        .cv
                        .wait(st)
                        .unwrap_or_else(|p| p.into_inner());
                }
                if st.failed {
                    drop(st);
                    panic!("model iteration failed before thread start");
                }
            }
            f()
        });
        JoinHandle { id, sched, inner }
    }
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Explore interleavings of `f`. The closure runs once per iteration on
/// the calling thread (model thread 0); it must join every thread it
/// spawns before returning. Panics (with the failing iteration's seed
/// in the message) as soon as any iteration fails.
pub fn check(name: &str, f: impl Fn()) {
    let iters = env_u64("DSI_LOOM_ITERS", 128);
    let preemptions = env_u64("DSI_LOOM_PREEMPTIONS", 8) as u32;
    for i in 0..iters {
        let seed =
            fnv1a(name) ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i + 1);
        let sched = Arc::new(Scheduler {
            state: StdMutex::new(SchedState {
                current: 0,
                finished: vec![false], // thread 0 = this test thread
                rng: Pcg32::new(seed),
                preemptions_left: preemptions,
                steps: 0,
                failed: false,
            }),
            cv: StdCondvar::new(),
        });
        CTX.with(|c| *c.borrow_mut() = Some((sched.clone(), 0)));
        let result = catch_unwind(AssertUnwindSafe(&f));
        CTX.with(|c| *c.borrow_mut() = None);
        match result {
            Ok(()) => {
                let st = sched.lock_state();
                assert!(
                    st.finished.iter().skip(1).all(|&d| d),
                    "model '{name}' iteration {i}: closure returned \
                     with unjoined threads"
                );
            }
            Err(e) => {
                // Wake any stragglers so they unwind too, then re-raise.
                {
                    let mut st = sched.lock_state();
                    st.failed = true;
                }
                sched.cv.notify_all();
                eprintln!(
                    "model '{name}' failed at iteration {i} \
                     (seed {seed:#x})"
                );
                resume_unwind(e);
            }
        }
    }
}
