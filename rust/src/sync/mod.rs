//! Synchronization facade for the DSI control plane.
//!
//! Every concurrency-bearing module imports its primitives from here
//! instead of `std::sync`. On a normal build this module is a pure
//! re-export of `std::sync` — zero cost, byte-identical behavior. Under
//! `--cfg loom` the same names resolve to instrumented wrappers
//! ([`shim`]) that yield to a deterministic bounded-preemption
//! scheduler ([`model`]), so the model tests in [`models`] can explore
//! thread interleavings of the real production code paths: broker
//! single-flight serves, `MemoryBudget` accounting, the Master lease
//! state machine, the lock-free observability counters, and the
//! client/trainer drain loop (via the [`model_yield`] hook).
//!
//! The checker explores sequentially-consistent interleavings only: it
//! catches lock/CAS/condvar protocol bugs (lost wakeups, double frees,
//! stranded loading slots, lease double-grants), not weak-memory
//! reordering bugs. The non-blocking TSan CI job covers the latter.
//!
//! Run the models with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --lib sync::
//! ```
//!
//! `DSI_LOOM_ITERS` (default 128) and `DSI_LOOM_PREEMPTIONS`
//! (default 8) bound the exploration.

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{
    Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(loom)]
mod shim;
#[cfg(loom)]
pub use shim::{
    atomic, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

#[cfg(loom)]
pub mod model;
#[cfg(all(loom, test))]
mod models;

/// Model scheduling hook for poll/park loops built on primitives the
/// loom shim cannot instrument (`std::sync::mpsc` channels). On a
/// normal build this is a no-op and the caller falls through to its
/// `park_timeout`. Under `--cfg loom` it hands the execution token to a
/// runnable peer ([`model::yield_blocked`]) — without it, a polling
/// loop that holds the token would spin forever without ever letting
/// the thread it is waiting on run.
#[inline]
pub fn model_yield() {
    #[cfg(loom)]
    model::yield_blocked();
}

/// Lock a mutex, recovering from poisoning instead of propagating the
/// panic. The protected state in this crate is counters, caches, and
/// lease tables that stay internally consistent at every await point,
/// so a panicking holder (e.g. one worker dying mid-decode) must not
/// cascade panics through every other session sharing the broker.
pub fn lock_or_recover<'a, T: ?Sized>(
    m: &'a Mutex<T>,
    ctx: &str,
) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| {
        eprintln!("dsi: recovering poisoned lock ({ctx})");
        poisoned.into_inner()
    })
}

/// [`RwLock::read`] with the same poison-recovery policy as
/// [`lock_or_recover`].
pub fn read_or_recover<'a, T: ?Sized>(
    l: &'a RwLock<T>,
    ctx: &str,
) -> RwLockReadGuard<'a, T> {
    l.read().unwrap_or_else(|poisoned| {
        eprintln!("dsi: recovering poisoned rwlock/read ({ctx})");
        poisoned.into_inner()
    })
}

/// [`RwLock::write`] with the same poison-recovery policy as
/// [`lock_or_recover`].
pub fn write_or_recover<'a, T: ?Sized>(
    l: &'a RwLock<T>,
    ctx: &str,
) -> RwLockWriteGuard<'a, T> {
    l.write().unwrap_or_else(|poisoned| {
        eprintln!("dsi: recovering poisoned rwlock/write ({ctx})");
        poisoned.into_inner()
    })
}

/// [`Condvar::wait`] with the same poison-recovery policy as
/// [`lock_or_recover`].
pub fn wait_or_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    ctx: &str,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|poisoned| {
        eprintln!("dsi: recovering poisoned lock after wait ({ctx})");
        poisoned.into_inner()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_or_recover_survives_poison() {
        let m = Arc::new(Mutex::new(0u32));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        });
        assert!(t.join().is_err());
        // A bare .lock().unwrap() would now panic; the helper recovers.
        *lock_or_recover(&m, "test") += 1;
        assert_eq!(*lock_or_recover(&m, "test"), 1);
    }

    #[test]
    fn rw_recover_survives_poison() {
        let l = Arc::new(RwLock::new(7u32));
        let l2 = l.clone();
        let t = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        });
        assert!(t.join().is_err());
        assert_eq!(*read_or_recover(&l, "test"), 7);
        *write_or_recover(&l, "test") = 8;
        assert_eq!(*read_or_recover(&l, "test"), 8);
    }
}
