//! Loom-style model tests for the riskiest DSI protocols.
//!
//! Each test runs the *production* code (no test doubles) under the
//! bounded-preemption scheduler in [`super::model`], so every lock
//! acquire, condvar wait, and atomic op is a potential context switch.
//! Compiled only under `--cfg loom` (see the module doc in
//! [`super`](crate::sync) for how to run them).

use super::model;
use super::model::thread;
use crate::broker::{
    ColumnBuffer, ColumnId, FetchedColumns, FetchedStripe, MemoryBudget,
    ServeOutcome, SharedColumn, StripeBuffer,
};
use crate::data::ColumnarBatch;
use crate::dpp::worker::WireBatch;
use crate::dpp::{Client, Master, TensorBatch};
use crate::dwrf::crypto::StreamCipher;
use crate::metrics::StageClock;
use crate::obs::Histogram;
use crate::schema::FeatureId;
use crate::tectonic::FileId;
use std::collections::HashSet;
use std::sync::Arc;
// Model *bookkeeping* (e.g. counting how often a fetch closure ran) uses
// raw std atomics on purpose: they assert on the model, they are not
// part of the protocol under test, and must not add scheduling points.
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

fn stripe_of(bytes: usize) -> crate::broker::SharedStripe {
    // approx_bytes counts labels at 4 bytes each.
    crate::broker::SharedStripe::Columnar(ColumnarBatch {
        num_rows: bytes / 4,
        labels: vec![0.0; bytes / 4],
        ..Default::default()
    })
}

fn fetched(bytes: usize) -> FetchedStripe {
    FetchedStripe {
        stripe: stripe_of(bytes),
        proj: HashSet::new(),
        fetched_bytes: bytes as u64,
        extents: 4,
        ios: 1,
    }
}

fn key(f: u64, s: usize) -> (FileId, usize) {
    (FileId(f), s)
}

fn col_of(bytes: usize) -> SharedColumn {
    // Meta counts labels at 4 bytes each.
    SharedColumn::Meta {
        labels: vec![0.0; bytes / 4],
        timestamps: Vec::new(),
        inverse: None,
        col_rows: bytes / 4,
    }
}

fn fetched_cols(ids: &[ColumnId], bytes_each: usize) -> FetchedColumns {
    FetchedColumns {
        cols: ids
            .iter()
            .map(|&c| (c, col_of(bytes_each), bytes_each as u64))
            .collect(),
        fetched_bytes: (ids.len() * bytes_each) as u64,
        extents: ids.len(),
        ios: 1,
    }
}

fn feat(id: u32) -> ColumnId {
    ColumnId::Feature(FeatureId(id))
}

/// Live per-column demand used by the column-grain models: row metadata
/// is infinitely hot (every projection needs it), features are as hot
/// as their id.
fn demand(c: ColumnId) -> f64 {
    match c {
        ColumnId::Meta => f64::MAX,
        ColumnId::Feature(f) => f.0 as f64,
    }
}

/// Protocol 1: lock-free `Histogram` record/merge. Two recorders and a
/// concurrent merging reader — no record is ever lost, counts are
/// monotone, and a snapshot never over-counts.
#[test]
fn model_histogram_record_merge() {
    model::check("histogram_record_merge", || {
        let h = Arc::new(Histogram::new());
        let h1 = h.clone();
        let t1 = thread::spawn(move || h1.record_ns(900));
        let h2 = h.clone();
        let t2 = thread::spawn(move || h2.record_ns(1_000_000));
        // Concurrent snapshot: may observe 0, 1, or 2 records, never
        // more (merge reads each bucket exactly once).
        let snap = Histogram::new();
        snap.merge(&h);
        let seen = snap.count();
        assert!(seen <= 2, "snapshot over-counted: {seen}");
        t1.join().unwrap();
        t2.join().unwrap();
        // Quiescent merge sees everything: no lost records.
        let total = Histogram::new();
        total.merge(&h);
        assert_eq!(total.count(), 2, "lost a record");
        assert!(total.count() >= seen, "count not monotone");
        assert_eq!(h.count(), 2);
    });
}

/// Protocol 2: `StageClock` concurrent `add` — nanosecond accumulation
/// never drops an update.
#[test]
fn model_stage_clock_concurrent_adds() {
    model::check("stage_clock_adds", || {
        let c = Arc::new(StageClock::default());
        let c1 = c.clone();
        let t1 = thread::spawn(move || c1.add(Duration::from_nanos(500)));
        let c2 = c.clone();
        let t2 = thread::spawn(move || c2.add(Duration::from_nanos(500)));
        t1.join().unwrap();
        t2.join().unwrap();
        assert!(
            (c.secs() - 1e-6).abs() < 1e-12,
            "lost a StageClock add: {}",
            c.secs()
        );
    });
}

/// Protocol 3a: broker `StripeBuffer` single-flight — two sessions
/// racing on the same key pay exactly one fetch in every interleaving,
/// and the last-consumer serve frees the entry and its budget.
#[test]
fn model_stripe_buffer_single_flight() {
    model::check("stripe_buffer_single_flight", || {
        let buf = Arc::new(StripeBuffer::new(MemoryBudget::new(1 << 20)));
        let fetches = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let buf = buf.clone();
            let fetches = fetches.clone();
            handles.push(thread::spawn(move || {
                // remaining = 1: one more registered serve is expected,
                // so the entry is cached (budget is ample → charged).
                let out = buf
                    .serve(key(1, 0), &[], 1, || {
                        fetches.fetch_add(1, Ordering::Relaxed);
                        Ok(fetched(400))
                    })
                    .unwrap();
                drop(out);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            fetches.load(Ordering::Relaxed),
            1,
            "single-flight violated: duplicated storage fetch"
        );
        // Last interested consumer: hit, then the entry + budget free.
        let out = buf
            .serve(key(1, 0), &[], 0, || panic!("must not refetch"))
            .unwrap();
        assert!(matches!(out, ServeOutcome::Hit { .. }));
        drop(out);
        assert_eq!(buf.len(), 0, "last-consumer entry not freed");
        assert_eq!(buf.budget().used(), 0, "budget leaked");
    });
}

/// Protocol 3b: `MemoryBudget` accounting under concurrent serves of
/// *different* keys with eviction pressure — `used` never exceeds
/// `total`, and releasing every key returns the pool to zero.
#[test]
fn model_stripe_buffer_eviction_accounting() {
    model::check("stripe_buffer_eviction_accounting", || {
        // Two 400-byte stripes against a 500-byte pool: at most one can
        // be cached; the other serves uncached or evicts the first.
        let buf = Arc::new(StripeBuffer::new(MemoryBudget::new(500)));
        let mut handles = Vec::new();
        for i in 0..2 {
            let buf = buf.clone();
            handles.push(thread::spawn(move || {
                let out =
                    buf.serve(key(1, i), &[], 1, || Ok(fetched(400)));
                drop(out.unwrap());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            buf.budget().used() <= 500,
            "budget overcommitted: {}",
            buf.budget().used()
        );
        buf.release(key(1, 0));
        buf.release(key(1, 1));
        assert_eq!(buf.budget().used(), 0, "budget leaked after release");
        assert_eq!(buf.len(), 0);
    });
}

/// Protocol 3c: bare `MemoryBudget` reserve/release — concurrent
/// balanced reserve/release pairs leave the pool empty and at full
/// capacity (the CAS loops neither lose nor double-count bytes).
#[test]
fn model_memory_budget_reserve_release() {
    model::check("memory_budget_reserve_release", || {
        let b = MemoryBudget::new(1000);
        let mut handles = Vec::new();
        for _ in 0..2 {
            let b = b.clone();
            handles.push(thread::spawn(move || {
                // 600 + 600 > 1000: at most one reservation can be live
                // at a time; each releases exactly what it reserved.
                if b.try_reserve(600) {
                    b.release(600);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.used(), 0, "budget leaked");
        assert!(b.try_reserve(1000), "pool not back at full capacity");
        b.release(1000);
    });
}

/// Protocol 4a: Master lease lifecycle — two workers draining a queue
/// concurrently: every split settles exactly once and the session
/// reaches `is_done` (no lost or double-served splits).
#[test]
fn model_master_lease_lifecycle() {
    model::check("master_lease_lifecycle", || {
        let m = Arc::new(Master::synthetic(3));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let m = m.clone();
            handles.push(thread::spawn(move || {
                let w = m.register_worker();
                while let Some(split) = m.fetch_split(w) {
                    m.complete_split(w, split.id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(m.is_done(), "splits stranded in queue or in flight");
        assert_eq!(m.progress(), (3, 3), "lost or duplicated completions");
    });
}

/// Protocol 4b: worker failure racing a completion — the split settles
/// exactly once (first completion wins), a dead worker never leases,
/// and a completed split is never requeued to a replacement worker.
#[test]
fn model_master_failure_requeues_only_incomplete() {
    model::check("master_failure_vs_completion", || {
        let m = Arc::new(Master::synthetic(1));
        let w1 = m.register_worker();
        let split = m.fetch_split(w1).expect("one split queued");
        let id = split.id;
        let mc = m.clone();
        let completer = thread::spawn(move || mc.complete_split(w1, id));
        let mf = m.clone();
        let failer = thread::spawn(move || mf.worker_failed(w1));
        completer.join().unwrap();
        failer.join().unwrap();
        // Dead workers never lease — even if the failure requeued.
        assert!(m.fetch_split(w1).is_none(), "dead worker leased a split");
        // A replacement worker must see nothing: the completion settled
        // the split, so any requeue raced by the failure was cancelled.
        let w2 = m.register_worker();
        assert!(
            m.fetch_split(w2).is_none(),
            "completed split was requeued"
        );
        assert!(m.is_done());
        assert_eq!(m.progress(), (1, 1));
    });
}

/// Protocol 5a: `ColumnBuffer` single-flight at column grain — two
/// sessions with *overlapping* projections of one stripe ([Meta, F1]
/// vs [Meta, F2]) pay for each column's fetch exactly once in every
/// interleaving: the shared Meta column is fetched by one serve and hit
/// by the other, the private features are fetched by their sole
/// requester, and dropping the stripe frees every byte.
#[test]
fn model_column_buffer_single_flight() {
    model::check("column_buffer_single_flight", || {
        let buf = Arc::new(ColumnBuffer::new(MemoryBudget::new(1 << 20)));
        let fetched = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for i in 0..2u32 {
            let buf = buf.clone();
            let fetched = fetched.clone();
            handles.push(thread::spawn(move || {
                let needed = [ColumnId::Meta, feat(i + 1)];
                // remaining = 1: one more registered serve expected, so
                // columns stay cached (budget is ample → all charged).
                let out = buf
                    .serve(key(1, 0), &needed, 1, &demand, |m| {
                        fetched.fetch_add(m.len(), Ordering::Relaxed);
                        Ok(fetched_cols(m, 200))
                    })
                    .unwrap();
                assert_eq!(out.cols.len(), 2, "column went unserved");
                out.hits
            }));
        }
        let mut hits = 0;
        for h in handles {
            hits += h.join().unwrap();
        }
        assert_eq!(
            fetched.load(Ordering::Relaxed),
            3,
            "single-flight violated: a column was fetched twice"
        );
        assert_eq!(hits, 1, "shared Meta column not hit by the peer");
        assert_eq!(buf.budget().used(), 600, "wrong bytes charged");
        buf.release_stripe(key(1, 0));
        assert_eq!(buf.len(), 0, "released stripe left columns behind");
        assert_eq!(buf.budget().used(), 0, "budget leaked");
    });
}

/// Protocol 5b: `MemoryBudget` accounting under concurrent column
/// serves with eviction pressure, plus popularity-aware admission —
/// `used` never exceeds `total`, release returns the pool to zero, and
/// (checked deterministically after the race) a cold column is refused
/// admission rather than displacing a hotter one.
#[test]
fn model_column_buffer_eviction_accounting() {
    model::check("column_buffer_eviction_accounting", || {
        // Two 400-byte columns against a 500-byte pool: at most one can
        // be cached; the other serves uncharged or evicts the first.
        let buf = Arc::new(ColumnBuffer::new(MemoryBudget::new(500)));
        let mut handles = Vec::new();
        for i in 0..2u32 {
            let buf = buf.clone();
            handles.push(thread::spawn(move || {
                let out = buf
                    .serve(key(1, i as usize), &[feat(i + 1)], 1, &demand, |m| {
                        Ok(fetched_cols(m, 400))
                    })
                    .unwrap();
                assert_eq!(out.cols.len(), 1, "column went unserved");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(
            buf.budget().used() <= 500,
            "budget overcommitted: {}",
            buf.budget().used()
        );
        buf.release_stripe(key(1, 0));
        buf.release_stripe(key(1, 1));
        assert_eq!(buf.budget().used(), 0, "budget leaked after release");
        assert_eq!(buf.len(), 0);
        // Popularity-aware admission, checked on the now-quiescent
        // buffer: hot feature 2 is cached, then cold feature 1 must be
        // served uncharged — never by displacing the hotter column.
        drop(
            buf.serve(key(1, 0), &[feat(2)], 1, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        drop(
            buf.serve(key(1, 1), &[feat(1)], 1, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        assert_eq!(buf.len(), 1, "cold column displaced a hot one");
        let out = buf
            .serve(key(1, 0), &[feat(2)], 0, &demand, |_| {
                panic!("hot column was evicted")
            })
            .unwrap();
        assert_eq!(out.hits, 1);
        drop(out);
        assert_eq!(buf.len(), 0, "last-consumer columns not freed");
        assert_eq!(buf.budget().used(), 0, "budget leaked");
    });
}

/// Protocol 6: the client/trainer drain loop — a worker-shaped sender
/// pushing wire batches through a bounded channel against the
/// *production* `Client::next_batch` poll/park loop. The channel itself
/// is `std::sync::mpsc` (exactly what production uses; the shim cannot
/// instrument it), so the two sides meet the scheduler differently:
/// the sender spins on `try_send` backpressure through
/// [`model::yield_blocked`], and the client's poll loop yields through
/// the `sync::model_yield` hook it calls before every park. Checked in
/// every interleaving: each batch is delivered exactly once, in send
/// order, and the client reports end-of-stream (`None`) only after the
/// sender has disconnected — never early, never hanging.
#[test]
fn model_client_drain_loop() {
    model::check("client_drain_loop", || {
        // Capacity 1 forces real backpressure: the sender must observe
        // `Full` whenever the client has not yet drained.
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        let sender = thread::spawn(move || {
            let cipher = StreamCipher::for_table("t");
            for seq in 0..3u64 {
                let tb = TensorBatch {
                    rows: 1,
                    dense: vec![seq as f32],
                    dense_names: vec![FeatureId(0)],
                    sparse: vec![],
                    labels: vec![1.0],
                };
                let mut wire = WireBatch::plain(
                    seq,
                    1,
                    false,
                    tb.to_wire(&cipher, seq),
                );
                loop {
                    match tx.try_send(wire) {
                        Ok(()) => break,
                        Err(std::sync::mpsc::TrySendError::Full(w)) => {
                            wire = w;
                            // Blocked on the consumer: hand the token
                            // over without spending preemption budget.
                            model::yield_blocked();
                        }
                        Err(
                            std::sync::mpsc::TrySendError::Disconnected(_),
                        ) => unreachable!("client dropped mid-stream"),
                    }
                }
            }
            // Closure end drops `tx`: the client must now see
            // `Disconnected`, not spin to its timeout.
        });
        let mut client = Client::new("t", vec![rx]);
        let mut seen = Vec::new();
        while let Some(tb) = client
            .next_batch(Duration::from_secs(60))
            .expect("wire decode failed")
        {
            seen.push(tb.dense[0]);
        }
        assert_eq!(
            seen,
            vec![0.0, 1.0, 2.0],
            "batch lost, duplicated, or reordered"
        );
        sender.join().unwrap();
    });
}
