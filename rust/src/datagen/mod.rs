//! Synthetic data generation: the model-serving simulator that produces
//! raw feature/event logs, and the end-to-end dataset builder that runs
//! the full offline path (serve → Scribe → ETL join → DWRF → Tectonic →
//! catalog).
//!
//! Statistics are calibrated to the paper's Tables 4–6: per-feature
//! coverage around the model's average, lognormal sparse lengths, Zipf
//! categorical ids, and CTR-like labels.

use crate::config::{RmConfig, SimScale};
use crate::data::Sample;
use crate::dwrf::{DwrfWriter, WriterOptions};
use crate::etl;
use crate::schema::{FeatureId, FeatureKind, Schema};
use crate::scribe::{EventLog, FeatureLog, Record, Scribe};
use crate::tectonic::Cluster;
use crate::util::rng::{Pcg32, Zipf};
use crate::warehouse::{Catalog, Partition, Table};
use anyhow::Result;

/// Sparse-id vocabulary size for the generator.
const VOCAB: u64 = 1 << 20;

/// Build the *materialized* schema for an RM at a simulation scale: the
/// full logged feature counts are scaled down proportionally (dense :
/// sparse ratio preserved); coverage / length statistics keep the paper's
/// table values.
pub fn materialized_schema(rng: &mut Pcg32, rm: &RmConfig, scale: &SimScale) -> Schema {
    let total = rm.dataset_features() as f64;
    let n = scale.materialized_features;
    let n_dense = ((rm.dataset_dense_features as f64 / total) * n as f64).round()
        as usize;
    let n_sparse = n - n_dense;
    Schema::synthetic(
        rng,
        n_dense.max(1),
        n_sparse.max(1),
        rm.avg_coverage,
        rm.avg_sparse_len,
    )
}

/// The model-serving framework simulator (§3.1.1): evaluates one
/// (user, item) request, generating the extensive feature set as model
/// input and monitoring the outcome event.
pub struct ServingSim {
    pub schema: Schema,
    zipf_ids: Zipf,
    ctr: f64,
    /// Upper bound of the random inter-arrival tick (seconds): larger
    /// values spread a partition's event timestamps across more of the
    /// day, which is what makes timestamp-recency predicates select
    /// realistic row fractions.
    tick_max: u64,
    next_request: u64,
    clock: u64,
}

impl ServingSim {
    pub fn new(schema: Schema, ctr: f64, epoch: u64) -> ServingSim {
        ServingSim {
            schema,
            zipf_ids: Zipf::new(4096, 1.05),
            ctr,
            tick_max: 5,
            next_request: 0,
            clock: epoch,
        }
    }

    /// Override the inter-arrival tick bound (default 5s).
    pub fn with_tick_max(mut self, tick_max: u64) -> ServingSim {
        self.tick_max = tick_max.max(1);
        self
    }

    /// Serve one request: emit the feature log and the (monitored) event.
    pub fn serve(&mut self, rng: &mut Pcg32) -> (FeatureLog, EventLog) {
        let request_id = self.next_request;
        self.next_request += 1;
        self.clock += 1 + rng.below(self.tick_max);
        let mut dense = Vec::new();
        let mut sparse = Vec::new();
        let mut scored = Vec::new();
        for f in &self.schema.features {
            if !rng.chance(f.coverage) {
                continue;
            }
            match f.kind {
                FeatureKind::Dense => {
                    dense.push((f.id.0, rng.normal_ms(0.0, 2.0) as f32));
                }
                FeatureKind::Sparse => {
                    let len = rng
                        .lognormal_mean(f.avg_len, 0.7)
                        .round()
                        .clamp(1.0, 512.0) as usize;
                    let ids = (0..len)
                        .map(|_| {
                            // Zipf bucket + uniform tail keeps ids skewed but
                            // spread over the vocabulary.
                            let bucket = self.zipf_ids.sample(rng) as u64;
                            bucket * (VOCAB / 4096) + rng.below(VOCAB / 4096)
                        })
                        .collect();
                    sparse.push((f.id.0, ids));
                }
                FeatureKind::ScoredSparse => {
                    let len = rng
                        .lognormal_mean(f.avg_len, 0.7)
                        .round()
                        .clamp(1.0, 512.0) as usize;
                    let pairs = (0..len)
                        .map(|_| (rng.below(VOCAB), rng.f32()))
                        .collect();
                    scored.push((f.id.0, pairs));
                }
            }
        }
        let flog = FeatureLog {
            request_id,
            timestamp: self.clock,
            dense,
            sparse,
            scored,
        };
        let elog = EventLog {
            request_id,
            timestamp: self.clock + 30 + rng.below(600),
            engaged: rng.chance(self.ctr),
        };
        (flog, elog)
    }

    /// Serve one *session*: the user's feature payload is evaluated once
    /// and fans out into `copies` impression logs — identical features,
    /// distinct request ids/timestamps, independent outcomes. This is
    /// the production duplication RecD exploits: payload-identical
    /// samples whose labels/timestamps differ.
    pub fn serve_session(
        &mut self,
        rng: &mut Pcg32,
        copies: usize,
    ) -> Vec<(FeatureLog, EventLog)> {
        let (first_f, first_e) = self.serve(rng);
        let mut out = Vec::with_capacity(copies.max(1));
        out.push((first_f, first_e));
        for _ in 1..copies.max(1) {
            let request_id = self.next_request;
            self.next_request += 1;
            self.clock += 1 + rng.below(self.tick_max);
            let base = &out[0].0;
            let flog = FeatureLog {
                request_id,
                timestamp: self.clock,
                dense: base.dense.clone(),
                sparse: base.sparse.clone(),
                scored: base.scored.clone(),
            };
            let elog = EventLog {
                request_id,
                timestamp: self.clock + 30 + rng.below(600),
                engaged: rng.chance(self.ctr),
            };
            out.push((flog, elog));
        }
        out
    }
}

/// Knobs for the partition generator — the statistics that determine
/// how selective pushed-down predicates are against the produced
/// warehouse.
#[derive(Clone, Debug)]
pub struct GenOptions {
    /// Mean session fan-out (payload-identical impressions); `<= 1` is
    /// the duplication-free path.
    pub dup_factor: usize,
    /// Positive-label rate — the label skew negative downsampling
    /// filters against.
    pub ctr: f64,
    /// Inter-arrival tick bound (seconds): spreads event timestamps so
    /// recency windows select stripe subsets instead of all-or-nothing.
    pub tick_max: u64,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            dup_factor: 1,
            ctr: 0.12,
            tick_max: 5,
        }
    }
}

/// Generate one day-partition worth of labeled samples through the real
/// offline path: serving sim → Scribe streams → ETL batch join.
pub fn generate_partition_samples(
    rng: &mut Pcg32,
    schema: &Schema,
    rows: usize,
    day: u32,
) -> Vec<Sample> {
    generate_partition_samples_with(rng, schema, rows, day, &GenOptions::default())
}

/// [`generate_partition_samples`] with a duplication factor: sessions fan
/// out into a geometric-ish number of payload-identical impressions
/// (mean `dup_factor`), scattered across the partition the way
/// interleaved production logs are. `dup_factor <= 1` is exactly the
/// duplication-free path (bit-identical output for a given seed).
pub fn generate_partition_samples_dup(
    rng: &mut Pcg32,
    schema: &Schema,
    rows: usize,
    day: u32,
    dup_factor: usize,
) -> Vec<Sample> {
    generate_partition_samples_with(
        rng,
        schema,
        rows,
        day,
        &GenOptions {
            dup_factor,
            ..Default::default()
        },
    )
}

/// The fully-parameterized partition generator: timestamps are stamped
/// from the day's epoch with `tick_max`-bounded inter-arrival gaps and
/// labels skewed to `ctr`, so generated warehouses expose realistic
/// selectivity to timestamp-recency and label predicates.
pub fn generate_partition_samples_with(
    rng: &mut Pcg32,
    schema: &Schema,
    rows: usize,
    day: u32,
    opts: &GenOptions,
) -> Vec<Sample> {
    let scribe = Scribe::new();
    let mut sim = ServingSim::new(schema.clone(), opts.ctr, day as u64 * 86_400)
        .with_tick_max(opts.tick_max);
    let fstream = "features";
    let estream = "events";
    if opts.dup_factor <= 1 {
        for _ in 0..rows {
            let (f, e) = sim.serve(rng);
            scribe.publish(fstream, Record::Feature(f));
            // Events arrive on their own stream (order independent of
            // features).
            scribe.publish(estream, Record::Event(e));
        }
        return etl::batch_join(&scribe, fstream, estream);
    }
    let mut pairs = Vec::with_capacity(rows);
    while pairs.len() < rows {
        let copies = (rng.geometric(opts.dup_factor as f64) as usize)
            .min(rows - pairs.len())
            .max(1);
        pairs.extend(sim.serve_session(rng, copies));
    }
    // Scatter sessions: a session's impressions spread through the day's
    // log instead of sitting adjacent (which generic compression could
    // otherwise absorb within a stripe).
    rng.shuffle(&mut pairs);
    for (f, e) in pairs {
        scribe.publish(fstream, Record::Feature(f));
        scribe.publish(estream, Record::Event(e));
    }
    etl::batch_join(&scribe, fstream, estream)
}

/// A built dataset: catalog entry + where its partitions live.
pub struct DatasetHandle {
    pub table_name: String,
    pub schema: Schema,
}

/// Build a complete synthetic dataset for an RM: all partitions written as
/// DWRF files into the Tectonic cluster and registered in the catalog.
pub fn build_dataset(
    cluster: &Cluster,
    catalog: &Catalog,
    rm: &RmConfig,
    scale: &SimScale,
    writer_opts: WriterOptions,
    seed: u64,
) -> Result<DatasetHandle> {
    build_dataset_dup(cluster, catalog, rm, scale, writer_opts, seed, 1)
}

/// [`build_dataset`] with a sample-duplication factor (see
/// [`generate_partition_samples_dup`]): models the production session
/// reuse the dedup subsystem exploits. Factor 1 is bit-identical to
/// [`build_dataset`].
pub fn build_dataset_dup(
    cluster: &Cluster,
    catalog: &Catalog,
    rm: &RmConfig,
    scale: &SimScale,
    writer_opts: WriterOptions,
    seed: u64,
    dup_factor: usize,
) -> Result<DatasetHandle> {
    build_dataset_with(
        cluster,
        catalog,
        rm,
        scale,
        writer_opts,
        seed,
        &GenOptions {
            dup_factor,
            ..Default::default()
        },
    )
}

/// [`build_dataset`] with full [`GenOptions`] control: duplication,
/// label skew (CTR), and timestamp spread.
pub fn build_dataset_with(
    cluster: &Cluster,
    catalog: &Catalog,
    rm: &RmConfig,
    scale: &SimScale,
    writer_opts: WriterOptions,
    seed: u64,
    opts: &GenOptions,
) -> Result<DatasetHandle> {
    let mut rng = Pcg32::new(seed);
    let schema = materialized_schema(&mut rng, rm, scale);
    let table_name = format!("{}_table", rm.id.name().to_lowercase());
    let dense_ids: Vec<FeatureId> = schema.dense().map(|f| f.id).collect();
    let sparse_ids: Vec<FeatureId> = schema.sparse().map(|f| f.id).collect();
    catalog.register(Table {
        name: table_name.clone(),
        schema: schema.clone(),
        partitions: Vec::new(),
    });
    for day in 0..scale.partitions as u32 {
        let mut part_rng = rng.fork(day as u64);
        let samples = generate_partition_samples_with(
            &mut part_rng,
            &schema,
            scale.rows_per_partition,
            day,
            opts,
        );
        let mut writer = DwrfWriter::new(
            &table_name,
            dense_ids.clone(),
            sparse_ids.clone(),
            writer_opts.clone(),
        );
        let rows = samples.len() as u64;
        writer.write_all(samples);
        let bytes = writer.finish();
        let fname = format!("warehouse/{table_name}/day={day}/part-0.dwrf");
        let file = cluster.create(&fname);
        cluster.append(file, &bytes)?;
        cluster.seal(file);
        catalog.add_partition(
            &table_name,
            Partition {
                day,
                file,
                rows,
                bytes: bytes.len() as u64,
            },
        );
    }
    Ok(DatasetHandle {
        table_name,
        schema,
    })
}

/// Dataset growth model for Fig 2: normalized dataset size and ingestion
/// bandwidth over `months`, matching the paper's reported 2× storage and
/// 4× bandwidth growth over 24 months (drivers: organic growth, reduced
/// downsampling, more engineered features; bandwidth additionally grows
/// with faster trainers).
pub fn growth_series(months: usize) -> (Vec<f64>, Vec<f64>) {
    let size_factor = 2.0f64;
    let bw_factor = 4.0f64;
    let size: Vec<f64> = (0..months)
        .map(|m| size_factor.powf(m as f64 / 23.0))
        .collect();
    let bw: Vec<f64> = (0..months)
        .map(|m| bw_factor.powf(m as f64 / 23.0))
        .collect();
    (size, bw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;
    use crate::tectonic::ClusterConfig;

    #[test]
    fn serving_sim_respects_coverage() {
        let mut rng = Pcg32::new(3);
        let schema = Schema::synthetic(&mut rng, 30, 10, 0.5, 10.0);
        let mut sim = ServingSim::new(schema.clone(), 0.1, 0);
        let mut logged = vec![0usize; schema.features.len()];
        let n = 400;
        for _ in 0..n {
            let (f, _) = sim.serve(&mut rng);
            for (id, _) in &f.dense {
                logged[*id as usize] += 1;
            }
            for (id, _) in &f.sparse {
                logged[*id as usize] += 1;
            }
            for (id, _) in &f.scored {
                logged[*id as usize] += 1;
            }
        }
        // Observed coverage tracks per-feature configured coverage.
        for f in &schema.features {
            let obs = logged[f.id.0 as usize] as f64 / n as f64;
            assert!(
                (obs - f.coverage).abs() < 0.15,
                "feature {:?}: obs {obs:.2} vs cfg {:.2}",
                f.id,
                f.coverage
            );
        }
    }

    #[test]
    fn generate_partition_labels_and_joins() {
        let mut rng = Pcg32::new(5);
        let schema = Schema::synthetic(&mut rng, 10, 5, 0.6, 8.0);
        let samples = generate_partition_samples(&mut rng, &schema, 200, 0);
        assert_eq!(samples.len(), 200, "every request joins");
        let pos = samples.iter().filter(|s| s.label == 1.0).count();
        assert!(pos > 5 && pos < 80, "CTR-ish positive rate, got {pos}");
        assert!(samples.iter().all(|s| !s.dense.is_empty() || !s.sparse.is_empty()));
    }

    #[test]
    fn dup_factor_injects_payload_duplicates() {
        let mut rng = Pcg32::new(8);
        let schema = Schema::synthetic(&mut rng, 10, 5, 0.6, 8.0);
        let samples =
            generate_partition_samples_dup(&mut rng, &schema, 300, 0, 4);
        assert_eq!(samples.len(), 300);
        let idx = crate::dedup::DedupIndex::analyze(&samples);
        assert!(idx.factor() > 2.0, "realized dup factor {}", idx.factor());
        // Duplicates carry independent labels: at CTR 0.12 a duplicated
        // payload eventually sees both outcomes.
        let pos = samples.iter().filter(|s| s.label == 1.0).count();
        assert!(pos > 5, "positives {pos}");
    }

    #[test]
    fn dup_factor_one_is_bit_identical_to_plain_generator() {
        let mut rng = Pcg32::new(9);
        let schema = Schema::synthetic(&mut rng, 10, 5, 0.6, 8.0);
        let mut a = rng.fork(1);
        let mut b = rng.fork(1);
        let s1 = generate_partition_samples(&mut a, &schema, 50, 0);
        let s2 = generate_partition_samples_dup(&mut b, &schema, 50, 0, 1);
        assert_eq!(s1, s2);
    }

    #[test]
    fn serve_session_copies_share_payload_not_identity() {
        let mut rng = Pcg32::new(4);
        let schema = Schema::synthetic(&mut rng, 8, 4, 0.9, 6.0);
        let mut sim = ServingSim::new(schema, 0.5, 0);
        let session = sim.serve_session(&mut rng, 5);
        assert_eq!(session.len(), 5);
        let first = &session[0].0;
        for (f, e) in &session[1..] {
            assert_eq!(f.dense, first.dense);
            assert_eq!(f.sparse, first.sparse);
            assert_eq!(f.scored, first.scored);
            assert_ne!(f.request_id, first.request_id);
            assert_eq!(e.request_id, f.request_id);
        }
        // Request ids unique across the session.
        let mut ids: Vec<u64> = session.iter().map(|(f, _)| f.request_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5);
    }

    #[test]
    fn gen_options_control_skew_and_spread() {
        let mut rng = Pcg32::new(12);
        let schema = Schema::synthetic(&mut rng, 10, 5, 0.6, 8.0);
        let n = 400;
        let tight = generate_partition_samples_with(
            &mut rng.fork(1),
            &schema,
            n,
            0,
            &GenOptions {
                tick_max: 2,
                ..Default::default()
            },
        );
        let spread = generate_partition_samples_with(
            &mut rng.fork(2),
            &schema,
            n,
            0,
            &GenOptions {
                tick_max: 200,
                ctr: 0.5,
                ..Default::default()
            },
        );
        let span = |xs: &[Sample]| {
            let min = xs.iter().map(|s| s.timestamp).min().unwrap();
            let max = xs.iter().map(|s| s.timestamp).max().unwrap();
            max - min
        };
        assert!(
            span(&spread) > span(&tight) * 10,
            "tick_max must spread timestamps: {} vs {}",
            span(&spread),
            span(&tight)
        );
        // CTR controls the label skew selectivity works against.
        let pos = |xs: &[Sample]| {
            xs.iter().filter(|s| s.label == 1.0).count() as f64
                / xs.len() as f64
        };
        assert!(pos(&tight) < 0.25, "default ctr ~0.12, got {}", pos(&tight));
        assert!(
            (pos(&spread) - 0.5).abs() < 0.12,
            "ctr 0.5, got {}",
            pos(&spread)
        );
    }

    #[test]
    fn build_dataset_end_to_end() {
        let cluster = Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions::default(),
            42,
        )
        .unwrap();
        let t = catalog.get(&h.table_name).unwrap();
        assert_eq!(t.partitions.len(), scale.partitions);
        assert_eq!(t.total_rows(), (scale.rows_per_partition * scale.partitions) as u64);
        assert!(cluster.logical_bytes() > 0);
        // 3x replication on disk.
        assert_eq!(cluster.stored_bytes(), 3 * cluster.logical_bytes());
    }

    #[test]
    fn materialized_schema_preserves_ratio() {
        let mut rng = Pcg32::new(1);
        let rm = RmConfig::get(RmId::Rm1);
        let scale = SimScale::standard();
        let s = materialized_schema(&mut rng, &rm, &scale);
        assert_eq!(s.features.len(), scale.materialized_features);
        let dense_frac = s.dense().count() as f64 / s.features.len() as f64;
        let want = rm.dataset_dense_features as f64 / rm.dataset_features() as f64;
        assert!((dense_frac - want).abs() < 0.05);
    }

    #[test]
    fn growth_matches_paper_factors() {
        let (size, bw) = growth_series(24);
        assert!((size[23] / size[0] - 2.0).abs() < 0.05);
        assert!((bw[23] / bw[0] - 4.0).abs() < 0.1);
        // Monotonic growth.
        assert!(size.windows(2).all(|w| w[1] >= w[0]));
    }
}
