//! Paper experiment registry: every table and figure of the paper's
//! evaluation, regenerable via `dsi paper --exp <id>` (or `--exp all`).
//!
//! Each driver prints the paper's reported values next to what this
//! reproduction measures; `--json` additionally emits machine-readable
//! results (consumed when updating EXPERIMENTS.md).

pub mod fleet;
pub mod harness;
pub mod preproc;
pub mod storage;

use crate::config::SimScale;
use crate::util::json::Json;
use anyhow::{bail, Result};

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "table2", "fig4", "fig5", "fig6", "table3", "table4",
    "table5", "table6", "fig7", "table7", "table8", "fig8", "table9", "fig9",
    "table10", "table11", "fig10", "table12", "insights", "power",
];

/// Run one experiment by id.
pub fn run(exp: &str, scale: &SimScale, seed: u64) -> Result<Json> {
    match exp {
        "fig1" => fleet::fig1(scale, seed),
        "fig2" => fleet::fig2(),
        "table2" => fleet::table2(seed),
        "fig4" => fleet::fig4(seed),
        "fig5" => fleet::fig5(seed),
        "fig6" => fleet::fig6(seed),
        "table3" => storage::table3(scale, seed),
        "table4" => fleet::table4(),
        "table5" => storage::table5(scale, seed),
        "table6" => storage::table6(scale, seed),
        "fig7" => fleet::fig7(seed),
        "table7" => preproc::table7(scale, seed),
        "table8" => preproc::table8(scale, seed),
        "fig8" => preproc::fig8(scale, seed),
        "table9" => preproc::table9(scale, seed),
        "fig9" => preproc::fig9(scale, seed),
        "table10" => fleet::table10(),
        "table11" => fleet::table11(),
        "fig10" => storage::fig10(scale, seed),
        "table12" => storage::table12(scale, seed),
        "insights" => fleet::insights(),
        "power" => fleet::power_analysis(scale, seed),
        other => bail!(
            "unknown experiment '{other}'; known: {}",
            ALL_EXPERIMENTS.join(", ")
        ),
    }
}

/// Run every experiment; returns a combined JSON object.
pub fn run_all(scale: &SimScale, seed: u64) -> Result<Json> {
    let mut all = Json::obj();
    for exp in ALL_EXPERIMENTS {
        println!("\n==================== {exp} ====================");
        let j = run(exp, scale, seed)?;
        all.set(exp, j);
    }
    Ok(all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("nope", &SimScale::tiny(), 1).is_err());
    }

    #[test]
    fn registry_covers_every_table_and_figure() {
        // Tables 1 (summary) and Fig 3 (architecture diagram) have no
        // experiment; everything else must be present.
        for required in [
            "fig1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9",
            "fig10", "table2", "table3", "table4", "table5", "table6",
            "table7", "table8", "table9", "table10", "table11", "table12",
        ] {
            assert!(
                ALL_EXPERIMENTS.contains(&required),
                "missing {required}"
            );
        }
    }
}
