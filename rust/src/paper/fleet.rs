//! Fleet-level drivers: Table 2 (feature lifecycle), Figs 4/5/6
//! (coordinated training), Fig 7 (byte popularity), Fig 1 (power split),
//! Fig 2 (growth), and the §7 insights / §7.5 power analyses.

use super::harness::{build_world, measure_pipeline};
use crate::config::{DeviceSpec, NodeSpec, RmConfig, RmId, SimScale, TrainerNodeSpec};
use crate::datagen::growth_series;
use crate::dpp::PipelineOptions;
use crate::dwrf::WriterOptions;
use crate::metrics::{Series, Table};
use crate::popularity::simulate_month;
use crate::power::{dsi_power_reduction, power_split, provision_storage, PowerSplit};
use crate::schema::{FeatureCatalog, FeatureStatus, Schema};
use crate::sched::{
    combo_iteration, daily_utilization, model_release_jobs, place_balanced,
    place_packed, top10_model_demand, JobStatus, REGIONS,
};
use crate::transforms::{all_op_names, Op, OpClass};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use anyhow::Result;

/// Table 2: feature lifecycle over a 6-month window.
pub fn table2(seed: u64) -> Result<Json> {
    let mut rng = Pcg32::new(seed);
    let mut cat = FeatureCatalog::new();
    cat.propose(&mut rng, 14614);
    let mut t = Table::new(
        "Table 2 — features created in 6 months, status 6 months later (paper | sim)",
        &["Beta", "Experimental", "Active", "Deprecated", "Total"],
    );
    t.row(&[
        format!("10148 | {}", cat.count(FeatureStatus::Beta)),
        format!("883 | {}", cat.count(FeatureStatus::Experimental)),
        format!("1650 | {}", cat.count(FeatureStatus::Active)),
        format!("1933 | {}", cat.count(FeatureStatus::Deprecated)),
        format!("14614 | {}", cat.total()),
    ]);
    t.print();
    println!(
        "  actively written to the dataset: {} features (experimental + \
         active + deprecated)",
        cat.actively_written()
    );
    let mut j = Json::obj();
    j.set("beta", cat.count(FeatureStatus::Beta))
        .set("experimental", cat.count(FeatureStatus::Experimental))
        .set("active", cat.count(FeatureStatus::Active))
        .set("deprecated", cat.count(FeatureStatus::Deprecated));
    Ok(j)
}

/// Table 4: features required by representative RC model versions.
pub fn table4() -> Result<Json> {
    let mut t = Table::new(
        "Table 4 — features used by representative RC models",
        &["Model Class", "# Dense", "# Sparse", "# Derived"],
    );
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        t.row(&[
            rm.id.name().into(),
            format!("{}", rm.used_dense_features),
            format!("{}", rm.used_sparse_features),
            format!("{}", rm.derived_features),
        ]);
        let mut o = Json::obj();
        o.set("dense", rm.used_dense_features)
            .set("sparse", rm.used_sparse_features)
            .set("derived", rm.derived_features);
        j.set(rm.id.name(), o);
    }
    t.print();
    Ok(j)
}

/// Table 10: compute-node generations + derived per-core ratios.
pub fn table10() -> Result<Json> {
    let mut t = Table::new(
        "Table 10 — DPP compute node generations",
        &[
            "Node",
            "Cores",
            "NIC (Gbps)",
            "Mem (GB)",
            "Peak MemBW (GB/s)",
            "MemBW/Core",
            "NIC/Core",
        ],
    );
    let mut j = Json::obj();
    for n in NodeSpec::all_generations() {
        t.row(&[
            n.name.into(),
            format!("{}", n.physical_cores),
            format!("{:.1}", n.nic_gbps),
            format!("{:.0}", n.memory_gb),
            format!("{:.0}", n.peak_mem_bw_gbps),
            format!("{:.1}", n.mem_bw_per_core()),
            format!("{:.2}", n.nic_bw_per_core()),
        ]);
        let mut o = Json::obj();
        o.set("membw_per_core", n.mem_bw_per_core())
            .set("nic_per_core", n.nic_bw_per_core());
        j.set(n.name, o);
    }
    t.print();
    println!(
        "  §6.3: NIC/core grows while memBW/core shrinks → memory \
         bandwidth becomes the preprocessing bottleneck."
    );
    Ok(j)
}

/// Table 11: the transform op inventory with class + GPU amenability.
pub fn table11() -> Result<Json> {
    let mut t = Table::new(
        "Table 11 — production preprocessing transforms",
        &["Op", "Class", "GPU/CPU speedup (paper §7.2 where given)"],
    );
    let examples: Vec<(&str, Op)> = vec![
        ("Cartesian", Op::Cartesian),
        ("Bucketize", Op::Bucketize { borders: vec![0.0] }),
        ("ComputeScore", Op::ComputeScore { mul: 1.0, add: 0.0 }),
        ("Enumerate", Op::Enumerate),
        ("PositiveModulus", Op::PositiveModulus { modulus: 10 }),
        ("IdListTransform", Op::IdListTransform),
        ("BoxCox", Op::BoxCox { lambda: 0.5 }),
        ("Logit", Op::Logit { eps: 1e-4 }),
        (
            "MapId",
            Op::MapId {
                mapping: Default::default(),
                default: 0,
            },
        ),
        ("FirstX", Op::FirstX { x: 8 }),
        ("GetLocalHour", Op::GetLocalHour { tz_offset_secs: 0 }),
        (
            "SigridHash",
            Op::SigridHash {
                salt: 0,
                modulus: 1 << 16,
            },
        ),
        ("NGram", Op::NGram { n: 2 }),
        ("Onehot", Op::Onehot { buckets: 16 }),
        ("Clamp", Op::Clamp { lo: 0.0, hi: 1.0 }),
        (
            "Sampling",
            Op::Sampling {
                rate: 0.5,
                seed: 0,
            },
        ),
    ];
    assert_eq!(examples.len(), all_op_names().len());
    let mut j = Json::obj();
    for (name, op) in &examples {
        let class = match op.class() {
            OpClass::DenseNorm => "dense norm",
            OpClass::SparseNorm => "sparse norm",
            OpClass::FeatureGen => "feature gen",
        };
        t.row(&[
            (*name).into(),
            class.into(),
            format!("{:.1}x", op.gpu_speedup()),
        ]);
        j.set(name, op.gpu_speedup());
    }
    t.print();
    println!(
        "  §6.4 cycle split target: feature gen ≈75%, sparse norm ≈20%, \
         dense norm ≈5% of transform cycles."
    );
    Ok(j)
}

/// Fig 1: storage/preprocessing/training power split per RM.
pub fn fig1(scale: &SimScale, seed: u64) -> Result<Json> {
    let mut t = Table::new(
        "Fig 1 — power split per training node (measured-model)",
        &["Model", "Storage %", "Preproc %", "Training %", "DSI > 50%?"],
    );
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        let split = rm_power_split(&rm, scale, seed)?;
        let (s, p, tr) = split.fracs();
        t.row(&[
            rm.id.name().into(),
            format!("{:.0}", s * 100.0),
            format!("{:.0}", p * 100.0),
            format!("{:.0}", tr * 100.0),
            if split.dsi_frac() > 0.5 { "yes" } else { "no" }.into(),
        ]);
        let mut o = Json::obj();
        o.set("storage", s).set("preproc", p).set("training", tr);
        j.set(rm.id.name(), o);
    }
    t.print();
    println!(
        "  paper: DSI (storage+preproc) power can exceed training power; \
         RM1/RM3 cross 50% in Fig 1."
    );
    Ok(j)
}

/// Power split for an RM using measured worker throughput + Table 3
/// dataset sizes + the observed average I/O size.
pub fn rm_power_split(rm: &RmConfig, scale: &SimScale, seed: u64) -> Result<PowerSplit> {
    let world = build_world(rm, scale, WriterOptions::default(), seed)?;
    let m = measure_pipeline(&world, PipelineOptions::default(), 64, seed)?;
    let sat = crate::resources::saturation(&m.cost, &NodeSpec::c_v1());
    let bytes_per_sample = m.tensor_tx_bytes as f64 / m.samples.max(1) as f64;
    let demand = crate::trainer::TrainerDemand::for_rm(rm, bytes_per_sample);
    let wpt = crate::trainer::workers_per_trainer(
        demand.samples_per_sec(),
        sat.max_samples_per_sec,
    );
    // Storage: demand per trainer node, observed average I/O size.
    let avg_io = m.storage.bytes_read as f64 / m.storage.reads.max(1) as f64;
    let read_gbps_per_trainer =
        demand.samples_per_sec() * m.cost.net_rx_bytes * 8.0 / 1e9;
    // Trainers sharing the dataset: total fleet demand for this model.
    let trainers_sharing = 100.0;
    let storage = provision_storage(
        rm.used_partitions_pb,
        3.0,
        read_gbps_per_trainer * trainers_sharing,
        avg_io,
        &DeviceSpec::hdd(),
    );
    Ok(power_split(
        &TrainerNodeSpec::zionex(),
        &NodeSpec::c_v1(),
        wpt,
        storage.watts(&DeviceSpec::hdd()) / trainers_sharing,
    ))
}

/// Fig 2: dataset size and ingestion bandwidth growth over 24 months.
pub fn fig2() -> Result<Json> {
    let (size, bw) = growth_series(24);
    let mut s1 = Series::new("dataset size");
    let mut s2 = Series::new("ingest bw");
    for (m, (&a, &b)) in size.iter().zip(bw.iter()).enumerate() {
        s1.push(m as f64, a);
        s2.push(m as f64, b);
    }
    println!("\n## Fig 2 — 24-month growth (normalized to month 0)");
    println!("  size ({:.2}x): {}", size[23], s1.sparkline(48));
    println!("  bw   ({:.2}x): {}", bw[23], s2.sparkline(48));
    println!("  paper: storage grew >2x, bandwidth >4x over two years");
    let mut j = Json::obj();
    j.set("size_growth", size[23]).set("bw_growth", bw[23]);
    Ok(j)
}

/// Fig 4: one RM1 release iteration's combo jobs.
pub fn fig4(seed: u64) -> Result<Json> {
    let mut rng = Pcg32::new(seed);
    let jobs = combo_iteration(&mut rng, 0, 82, 10.0);
    let mut sorted = jobs.clone();
    sorted.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
    println!("\n## Fig 4 — 82 combo jobs in one RM1 release iteration");
    let glyph = |s: JobStatus| match s {
        JobStatus::Completed => '█',
        JobStatus::Killed => '▒',
        JobStatus::Failed => '░',
    };
    for (i, chunk) in sorted.chunks(20).enumerate() {
        let line: String = chunk
            .iter()
            .map(|x| glyph(x.status))
            .collect();
        println!("  jobs {:>2}-{:<2}: {}", i * 20, i * 20 + chunk.len() - 1, line);
    }
    let completed =
        jobs.iter().filter(|x| x.status == JobStatus::Completed).count();
    let killed = jobs.iter().filter(|x| x.status == JobStatus::Killed).count();
    let failed = jobs.iter().filter(|x| x.status == JobStatus::Failed).count();
    let mut durs: Vec<f64> = jobs.iter().map(|x| x.duration).collect();
    durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "  completed {completed} / killed {killed} / failed {failed}; \
         duration p50 {:.1}d p95 {:.1}d max {:.1}d (skewed, some >10d)",
        durs[durs.len() / 2],
        durs[(durs.len() as f64 * 0.95) as usize],
        durs.last().unwrap()
    );
    let mut j = Json::obj();
    j.set("completed", completed)
        .set("killed", killed)
        .set("failed", failed)
        .set("max_duration", *durs.last().unwrap());
    Ok(j)
}

/// Fig 5: a year of daily peak compute across collaborative jobs.
pub fn fig5(seed: u64) -> Result<Json> {
    let mut rng = Pcg32::new(seed);
    let mut jobs = Vec::new();
    for m in 0..60 {
        let scale = 1.0 / (m as f64 + 1.0).powf(0.6);
        let cycle = 30.0 + rng.f64() * 40.0;
        jobs.extend(model_release_jobs(&mut rng, m, 365.0, cycle, scale));
    }
    let days = daily_utilization(&jobs, 365);
    let mut s = Series::new("daily util");
    for (d, &u) in days.iter().enumerate() {
        s.push(d as f64, u);
    }
    let n = s.normalized();
    println!("\n## Fig 5 — normalized daily compute over one year ({} jobs)", jobs.len());
    println!("  {}", n.sparkline(72));
    let mean = days.iter().sum::<f64>() / days.len() as f64;
    let peak = days.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "  peak/mean = {:.2} — distinct peaks where many models run combo \
         jobs concurrently (must provision for these, §4.2)",
        peak / mean
    );
    let mut j = Json::obj();
    j.set("peak_over_mean", peak / mean).set("jobs", jobs.len());
    Ok(j)
}

/// Fig 6: top-10 model demand split across 5 regions + §7.3 bin-packing.
pub fn fig6(seed: u64) -> Result<Json> {
    let mut rng = Pcg32::new(seed);
    let demand = top10_model_demand();
    let balanced = place_balanced(&mut rng, &demand);
    let mut t = Table::new(
        "Fig 6 — compute demand of top-10 models by region (normalized to J)",
        &["Model", "R1", "R2", "R3", "R4", "R5", "Total"],
    );
    for (m, row) in balanced.demand.iter().enumerate() {
        let name = (b'A' + m as u8) as char;
        let mut cells = vec![name.to_string()];
        for r in 0..REGIONS {
            cells.push(format!("{:.2}", row[r]));
        }
        cells.push(format!("{:.2}", demand[m]));
        t.row(&cells);
    }
    t.print();
    let total: f64 = demand.iter().sum();
    let packed = place_packed(&demand, total / REGIONS as f64 * 1.25);
    println!(
        "  balanced placement: {} dataset copies; bin-packed: {} copies \
         (−{:.0}% replica storage, §7.3)",
        balanced.dataset_copies,
        packed.dataset_copies,
        (1.0 - packed.dataset_copies as f64 / balanced.dataset_copies as f64)
            * 100.0
    );
    let mut j = Json::obj();
    j.set("balanced_copies", balanced.dataset_copies)
        .set("packed_copies", packed.dataset_copies);
    Ok(j)
}

/// Fig 7: byte-popularity CDFs for RM1–3.
pub fn fig7(seed: u64) -> Result<Json> {
    println!("\n## Fig 7 — CDF of popular bytes vs I/O absorbed (1 month of jobs)");
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        let mut rng = Pcg32::new(seed ^ rm.id.index() as u64);
        let schema = Schema::synthetic(
            &mut rng,
            400,
            120,
            rm.avg_coverage,
            rm.avg_sparse_len,
        );
        let stats = simulate_month(&mut rng, &rm, &schema, 150);
        let frac80 = stats.bytes_for_io(0.8);
        let cdf = stats.cdf();
        let mut s = Series::new("cdf");
        for &(x, y) in &cdf {
            s.push(x, y);
        }
        println!(
            "  {}: {} | {:.0}% of bytes serve 80% of I/O (paper: {:.0}%)",
            rm.id.name(),
            s.sparkline(48),
            frac80 * 100.0,
            rm.paper_bytes_for_80pct_io * 100.0
        );
        let mut o = Json::obj();
        o.set("bytes_for_80pct_io", frac80)
            .set("paper", rm.paper_bytes_for_80pct_io);
        j.set(rm.id.name(), o);
    }
    println!(
        "  shape: RM3 most concentrated (fewest bytes for 80% of I/O), \
         matching the paper's 18% vs RM1's 39%."
    );
    Ok(j)
}

/// §7.2 insights: heterogeneous storage media + transform acceleration +
/// kernel batching.
pub fn insights() -> Result<Json> {
    let hdd = DeviceSpec::hdd();
    let ssd = DeviceSpec::ssd();
    let mut t = Table::new(
        "§7.2 — storage media trade-off (per watt, vs HDD)",
        &["Medium", "IOPS/W", "Capacity/W (TB)", "IOPS/W vs HDD", "Cap/W vs HDD"],
    );
    for d in [&hdd, &ssd] {
        t.row(&[
            d.name.into(),
            format!("{:.1}", d.iops_per_watt()),
            format!("{:.2}", d.capacity_per_watt_tb()),
            format!("{:.0}%", d.iops_per_watt() / hdd.iops_per_watt() * 100.0),
            format!(
                "{:.0}%",
                d.capacity_per_watt_tb() / hdd.capacity_per_watt_tb() * 100.0
            ),
        ]);
    }
    t.print();
    println!(
        "  paper: SSD ≈326% IOPS/W but ≈9% capacity/W → tier popular \
         features (Fig 7) onto flash, keep capacity on HDD."
    );

    // Live tiering experiment: Fig-7 popularity says ~40% of bytes serve
    // 80% of I/O — admit exactly those bytes to a bounded SSD tier and
    // measure the service-time (≈power) cut on a skewed read workload.
    {
        use crate::dwrf::IoRange;
        use crate::tectonic::{Cluster, ClusterConfig, TieredStore};
        use crate::util::rng::{Pcg32, Zipf};
        let hdd_cluster = std::sync::Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 1 << 20,
            ..Default::default()
        }));
        let f = hdd_cluster.create("tiering-exp");
        let n_regions = 100usize;
        let region = 16_384u64;
        hdd_cluster
            .append(f, &vec![0xABu8; n_regions * region as usize])
            .unwrap();
        // Popularity over regions: Zipf; admit the hottest 40% of bytes.
        let zipf = Zipf::new(n_regions, 1.1);
        let tier =
            TieredStore::new(hdd_cluster, 2, (n_regions as u64 * region) * 2 / 5);
        for r in 0..(n_regions * 2 / 5) as u64 {
            tier.admit(
                f,
                IoRange {
                    offset: r * region,
                    len: region,
                },
            )
            .unwrap();
        }
        tier.reset_stats();
        let mut rng = Pcg32::new(99);
        for _ in 0..2000 {
            let r = zipf.sample(&mut rng) as u64;
            tier.read_range(
                f,
                IoRange {
                    offset: r * region + rng.below(region - 2048),
                    len: 2048,
                },
            )
            .unwrap();
        }
        let tiered_secs = tier.total_device_secs();
        let hit = tier.hit_rate();
        // Same workload, no tier.
        let cold = TieredStore::new(tier.hdd.clone(), 2, 0);
        cold.reset_stats();
        let mut rng = Pcg32::new(99);
        for _ in 0..2000 {
            let r = zipf.sample(&mut rng) as u64;
            cold.read_range(
                f,
                IoRange {
                    offset: r * region + rng.below(region - 2048),
                    len: 2048,
                },
            )
            .unwrap();
        }
        let cold_secs = cold.total_device_secs();
        println!(
            "  tiering experiment: hottest 40% of bytes on SSD → hit rate \
             {:.0}%, storage service time {:.2}s → {:.2}s ({:.1}x less \
             disk-time ≈ {:.1}x fewer IOPS-provisioned HDD nodes)",
            hit * 100.0,
            cold_secs,
            tiered_secs,
            cold_secs / tiered_secs.max(1e-12),
            cold_secs / tiered_secs.max(1e-12),
        );
    }
    // Kernel-batching experiment: one op over 1000 fused features vs 1000
    // per-feature invocations (the CPU-side analogue of the paper's
    // >1000x GPU launch-overhead observation).
    let op = Op::SigridHash {
        salt: 1,
        modulus: 1 << 16,
    };
    let per_feature_elems = 32usize;
    let n_features = 1000usize;
    let mk = |n_rows: usize| crate::transforms::Value::Sparse {
        offsets: (0..=n_rows as u32).collect(),
        ids: (0..n_rows as u64).collect(),
        scores: None,
    };
    let small = mk(per_feature_elems);
    let big = mk(per_feature_elems * n_features);
    let t0 = std::time::Instant::now();
    for _ in 0..n_features {
        std::hint::black_box(op.apply(&[&small]).unwrap());
    }
    let separate = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    std::hint::black_box(op.apply(&[&big]).unwrap());
    let fused = t0.elapsed().as_secs_f64();
    // On a GPU each per-feature apply is a kernel launch + host-to-device
    // transfer (~10 µs launch alone); the fused call pays it once. The
    // paper's >1000x comes from that per-launch overhead.
    const GPU_LAUNCH_SECS: f64 = 10e-6;
    let gpu_separate = n_features as f64 * GPU_LAUNCH_SECS
        + separate / 10.0; // compute itself accelerates ~10x
    let gpu_fused = GPU_LAUNCH_SECS + fused / 10.0;
    let modeled = gpu_separate / gpu_fused.max(1e-12);
    println!(
        "  kernel batching: 1000 per-feature applies {:.2}ms vs 1 fused \
         {:.2}ms on CPU ({:.1}x — CPUs have no launch overhead); with a \
         10µs/launch GPU model: {:.0}x (paper: >1000x observed on V100)",
        separate * 1e3,
        fused * 1e3,
        separate / fused.max(1e-9),
        modeled,
    );
    let mut j = Json::obj();
    j.set("ssd_iops_per_watt_ratio", ssd.iops_per_watt() / hdd.iops_per_watt())
        .set(
            "ssd_cap_per_watt_ratio",
            ssd.capacity_per_watt_tb() / hdd.capacity_per_watt_tb(),
        )
        .set("batching_speedup_cpu", separate / fused.max(1e-9))
        .set("batching_speedup_gpu_model", modeled);
    Ok(j)
}

/// §7.5: DSI power reduction from the measured Table 12 gains.
pub fn power_analysis(scale: &SimScale, seed: u64) -> Result<Json> {
    // Measure the Table 12 end states for RM1.
    let stages = super::storage::table12(scale, seed)?;
    let dpp = stages.get("dpp").unwrap();
    let storage = stages.get("storage").unwrap();
    let (dpp_gain, storage_gain) = match (dpp, storage) {
        (Json::Arr(d), Json::Arr(s)) => (
            d.last().unwrap().as_f64().unwrap(),
            s.last().unwrap().as_f64().unwrap(),
        ),
        _ => (1.0, 1.0),
    };
    let rm = RmConfig::get(RmId::Rm1);
    let split = rm_power_split(&rm, scale, seed)?;
    let reduction = dsi_power_reduction(&split, dpp_gain, storage_gain);
    let paper_reduction = dsi_power_reduction(
        &PowerSplit {
            storage_w: split.storage_w,
            preproc_w: split.preproc_w,
            training_w: split.training_w,
        },
        2.94,
        2.41,
    );
    println!("\n## §7.5 — co-designed optimization power impact");
    println!(
        "  measured gains: DPP {dpp_gain:.2}x, storage {storage_gain:.2}x \
         (paper: 2.94x / 2.41x)"
    );
    println!(
        "  → DSI power reduction {reduction:.2}x on our power split \
         (paper reports 2.59x; our split would give {paper_reduction:.2}x \
         at the paper's gains)"
    );
    let mut j = Json::obj();
    j.set("dpp_gain", dpp_gain)
        .set("storage_gain", storage_gain)
        .set("reduction", reduction);
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_distribution() {
        let j = table2(3).unwrap();
        let beta = j.get("beta").unwrap().as_f64().unwrap();
        assert!((beta - 10148.0).abs() < 600.0);
    }

    #[test]
    fn fig7_rm3_most_concentrated() {
        let j = fig7(9).unwrap();
        let f = |k: &str| {
            j.get(k)
                .unwrap()
                .get("bytes_for_80pct_io")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(f("RM3") < f("RM1"));
    }

    #[test]
    fn insights_ratios() {
        let j = insights().unwrap();
        assert!(j.get("ssd_iops_per_watt_ratio").unwrap().as_f64().unwrap() > 3.0);
        assert!(j.get("ssd_cap_per_watt_ratio").unwrap().as_f64().unwrap() < 0.5);
        assert!(
            j.get("batching_speedup_gpu_model").unwrap().as_f64().unwrap()
                > 50.0
        );
    }
}
