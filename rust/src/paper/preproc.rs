//! Online-preprocessing experiment drivers: Tables 7, 8, 9 and
//! Figs 8, 9.

use super::harness::{
    build_world, measure_loading_cost_per_byte, measure_pipeline,
};
use crate::config::{NodeSpec, RmConfig, SimScale, TrainerNodeSpec};
use crate::dpp::PipelineOptions;
use crate::dwrf::WriterOptions;
use crate::metrics::{Series, Table};
use crate::resources::{saturation, LoadingCost, PerSampleCost};
use crate::trainer::{colocated_preprocessing, workers_per_trainer, TrainerDemand};
use crate::util::json::Json;
use anyhow::Result;

/// Measure the per-sample pipeline cost for one RM (shared by several
/// drivers).
pub fn measured_cost(rm: &RmConfig, scale: &SimScale, seed: u64) -> Result<(PerSampleCost, f64, f64)> {
    let world = build_world(rm, scale, WriterOptions::default(), seed)?;
    let m = measure_pipeline(&world, PipelineOptions::default(), 64, seed)?;
    let bytes_per_sample = m.tensor_tx_bytes as f64 / m.samples.max(1) as f64;
    Ok((m.cost, bytes_per_sample, m.worker_sps))
}

/// Table 8: per-trainer-node GPU ingestion demand.
pub fn table8(scale: &SimScale, seed: u64) -> Result<Json> {
    let mut t = Table::new(
        "Table 8 — GPU trainer ingestion per 8-GPU node",
        &["", "RM1", "RM2", "RM3"],
    );
    let mut gbps = Vec::new();
    let mut sps = Vec::new();
    for rm in RmConfig::all() {
        let (_, bytes_per_sample, _) = measured_cost(&rm, scale, seed)?;
        let d = TrainerDemand::for_rm(&rm, bytes_per_sample);
        gbps.push(rm.trainer_node_gbps);
        sps.push(d.samples_per_sec());
    }
    t.row(&[
        "GPU Trainer Throughput (GB/s, paper)".into(),
        format!("{:.2}", gbps[0]),
        format!("{:.2}", gbps[1]),
        format!("{:.2}", gbps[2]),
    ]);
    t.row(&[
        "Implied demand (samples/s @ measured bytes/sample)".into(),
        format!("{:.0}", sps[0]),
        format!("{:.0}", sps[1]),
        format!("{:.0}", sps[2]),
    ]);
    t.print();
    println!(
        "  demand varies {:.1}x across models (paper: >3.5x)",
        gbps.iter().cloned().fold(0.0f64, f64::max)
            / gbps.iter().cloned().fold(f64::INFINITY, f64::min)
    );
    let mut j = Json::obj();
    j.set("gbps", gbps).set("samples_per_sec", sps);
    Ok(j)
}

/// Table 7: GPU stall with on-host preprocessing (the no-DPP baseline).
pub fn table7(scale: &SimScale, seed: u64) -> Result<Json> {
    let rm = RmConfig::get(crate::config::RmId::Rm1);
    let (cost, bytes_per_sample, _) = measured_cost(&rm, scale, seed)?;
    let demand = TrainerDemand::for_rm(&rm, bytes_per_sample);
    let r = colocated_preprocessing(
        &demand,
        &cost,
        &TrainerNodeSpec::v100_node(),
        4.0,
    );
    let mut t = Table::new(
        "Table 7 — RM1 with preprocessing on trainer-host CPUs (paper | measured model)",
        &["% GPU Stall Time", "% CPU Utilization", "% Memory BW Utilization"],
    );
    t.row(&[
        format!("56 | {:.0}", r.gpu_stall_frac * 100.0),
        format!("92 | {:.0}", r.cpu_util * 100.0),
        format!("54 | {:.0}", r.mem_bw_util * 100.0),
    ]);
    t.print();
    println!(
        "  achievable {:.0} sps vs demanded {:.0} sps → stalls; DPP \
         disaggregation removes them (§3.2.1)",
        r.achievable_sps, r.demanded_sps
    );
    let mut j = Json::obj();
    j.set("stall", r.gpu_stall_frac)
        .set("cpu", r.cpu_util)
        .set("membw", r.mem_bw_util);
    Ok(j)
}

/// Table 9: DPP worker throughput per RM + #workers per trainer node.
pub fn table9(scale: &SimScale, seed: u64) -> Result<Json> {
    let mut t = Table::new(
        "Table 9 — DPP worker characterization (paper | measured-model on C-v1)",
        &[
            "Model",
            "kQPS",
            "Storage RX (GB/s)",
            "Transform RX (GB/s)",
            "Transform TX (GB/s)",
            "#Workers/Trainer",
        ],
    );
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        let world = build_world(&rm, scale, WriterOptions::default(), seed)?;
        let m = measure_pipeline(&world, PipelineOptions::default(), 64, seed)?;
        let sat = saturation(&m.cost, &NodeSpec::c_v1());
        let kqps = sat.max_samples_per_sec / 1e3;
        let storage_rx = sat.max_samples_per_sec * m.cost.net_rx_bytes / 1e9;
        let xform_rx = sat.max_samples_per_sec
            * (m.cost.net_rx_bytes
                + m.cost.resident_bytes)
            / 1e9;
        let xform_tx = sat.max_samples_per_sec * m.cost.net_tx_bytes / 1e9;
        let bytes_per_sample = m.tensor_tx_bytes as f64 / m.samples.max(1) as f64;
        let demand = TrainerDemand::for_rm(&rm, bytes_per_sample);
        let wpt = workers_per_trainer(
            demand.samples_per_sec(),
            sat.max_samples_per_sec,
        );
        t.row(&[
            rm.id.name().into(),
            format!("{:.3} | {:.3}", rm.paper_worker_kqps, kqps),
            format!("{:.1} | {:.2}", rm.paper_storage_rx_gbps, storage_rx),
            format!("{:.2} | {:.2}", rm.paper_transform_rx_gbps, xform_rx),
            format!("{:.2} | {:.2}", rm.paper_transform_tx_gbps, xform_tx),
            format!("{:.2} | {:.2}", rm.paper_workers_per_trainer, wpt),
        ]);
        let mut o = Json::obj();
        o.set("kqps", kqps)
            .set("workers_per_trainer", wpt)
            .set("bottleneck", sat.bottleneck.name());
        j.set(rm.id.name(), o);
    }
    t.print();
    println!(
        "  shape: RM3 highest QPS / most workers per trainer; RM1 \
         transform-heavy; absolute numbers differ (simulated substrate)."
    );
    Ok(j)
}

/// Fig 8: trainer front-end CPU / memBW utilization vs loading rate.
pub fn fig8(_scale: &SimScale, seed: u64) -> Result<Json> {
    let cost_per_byte = measure_loading_cost_per_byte(seed);
    let lc = LoadingCost::standard(cost_per_byte);
    let node = TrainerNodeSpec::v100_node();
    let mut cpu_series = Series::new("CPU util");
    let mut mem_series = Series::new("MemBW util");
    let mut t = Table::new(
        "Fig 8 — trainer data-loading resource use vs throughput (V100 node)",
        &["Loading GB/s", "CPU util %", "MemBW util %", "NIC util %"],
    );
    for step in 1..=20 {
        let gbps_bytes = step as f64; // GB/s of tensor bytes
        let (cpu, mem) = lc.trainer_utilization(&node, gbps_bytes * 8.0);
        let nic = gbps_bytes * 8.0 / node.frontend_nic_gbps;
        cpu_series.push(gbps_bytes, cpu);
        mem_series.push(gbps_bytes, mem);
        t.row(&[
            format!("{gbps_bytes:.0}"),
            format!("{:.0}", cpu * 100.0),
            format!("{:.0}", mem * 100.0),
            format!("{:.0}", nic * 100.0),
        ]);
    }
    t.print();
    println!("  cpu:    {}", cpu_series.sparkline(40));
    println!("  membw:  {}", mem_series.sparkline(40));
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        let (cpu, mem) = lc.trainer_utilization(&node, rm.trainer_node_gbps * 8.0);
        println!(
            "  at {}'s {:.2} GB/s: CPU {:.0}%, memBW {:.0}% (paper: up to \
             40% CPU / 55% memBW across RMs)",
            rm.id.name(),
            rm.trainer_node_gbps,
            cpu * 100.0,
            mem * 100.0
        );
        let mut o = Json::obj();
        o.set("cpu", cpu).set("membw", mem);
        j.set(rm.id.name(), o);
    }
    j.set("cpu_secs_per_byte", cost_per_byte);
    Ok(j)
}

/// Fig 9: DPP worker utilization at saturation per RM, with the CPU
/// split into transformation / extraction / misc.
pub fn fig9(scale: &SimScale, seed: u64) -> Result<Json> {
    let mut t = Table::new(
        "Fig 9 — DPP worker utilization at saturation (C-v1)",
        &[
            "Model",
            "CPU total %",
            "  transform %",
            "  extract %",
            "  misc %",
            "Mem cap %",
            "MemBW %",
            "Bottleneck",
        ],
    );
    let mut j = Json::obj();
    for rm in RmConfig::all() {
        let world = build_world(&rm, scale, WriterOptions::default(), seed)?;
        let m = measure_pipeline(&world, PipelineOptions::default(), 64, seed)?;
        let sat = saturation(&m.cost, &NodeSpec::c_v1());
        let u = sat.at_saturation;
        let cpu = u.cpu.min(1.0);
        t.row(&[
            rm.id.name().into(),
            format!("{:.0}", cpu * 100.0),
            format!("{:.0}", cpu * m.cost.frac_transform * 100.0),
            format!("{:.0}", cpu * m.cost.frac_extract * 100.0),
            format!("{:.0}", cpu * m.cost.frac_misc * 100.0),
            format!("{:.0}", u.mem_cap * 100.0),
            format!("{:.0}", u.mem_bw * 100.0),
            sat.bottleneck.name().into(),
        ]);
        let mut o = Json::obj();
        o.set("cpu", cpu)
            .set("frac_transform", m.cost.frac_transform)
            .set("frac_extract", m.cost.frac_extract)
            .set("membw", u.mem_bw)
            .set("bottleneck", sat.bottleneck.name());
        j.set(rm.id.name(), o);
    }
    t.print();
    println!(
        "  paper shape: RM1 CPU+memBW bound (expensive transforms); RM3 \
         memory-capacity pressure; transform cycles dominate extraction."
    );
    // §6.3's C-v2 projection: RM2 flips to memory-bandwidth-bound.
    let rm2 = RmConfig::get(crate::config::RmId::Rm2);
    let world = build_world(&rm2, scale, WriterOptions::default(), seed)?;
    let m = measure_pipeline(&world, PipelineOptions::default(), 64, seed)?;
    for node in [NodeSpec::c_v1(), NodeSpec::c_v2(), NodeSpec::c_vsota()] {
        let sat = saturation(&m.cost, &node);
        println!(
            "  RM2 on {}: {:.0} samples/s, bottleneck = {}",
            node.name, sat.max_samples_per_sec, sat.bottleneck.name()
        );
    }
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_reports_substantial_stall() {
        let j = table7(&SimScale::tiny(), 7).unwrap();
        let stall = j.get("stall").unwrap().as_f64().unwrap();
        assert!(stall > 0.2, "stall {stall}");
        let cpu = j.get("cpu").unwrap().as_f64().unwrap();
        assert!(cpu > 0.8, "cpu {cpu}");
    }

    #[test]
    fn table9_rm3_needs_most_workers() {
        let j = table9(&SimScale::tiny(), 7).unwrap();
        let wpt = |k: &str| {
            j.get(k)
                .unwrap()
                .get("workers_per_trainer")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // Paper: RM3 55.2 > RM1 24.2 > RM2 9.4.
        assert!(wpt("RM3") > wpt("RM2"), "{} vs {}", wpt("RM3"), wpt("RM2"));
    }
}
