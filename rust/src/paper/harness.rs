//! Shared experiment machinery: build an RM-shaped world (dataset in
//! Tectonic + catalog), run a measured single-threaded worker pipeline
//! over it, and collect the cost/throughput numbers the drivers print.

use crate::config::{RmConfig, SimScale};
use crate::datagen::build_dataset;
use crate::dpp::{Master, PipelineOptions, SessionSpec, WorkerCore};
use crate::dwrf::{Projection, WriterOptions};
use crate::metrics::EtlMetrics;
use crate::popularity::{simulate_month, AccessStats};
use crate::resources::PerSampleCost;
use crate::schema::{FeatureId, Schema};
use crate::tectonic::{Cluster, ClusterConfig, IoStats};
use crate::transforms::dag::session_dag;
use crate::util::rng::Pcg32;
use crate::warehouse::Catalog;
use anyhow::Result;
use std::sync::Arc;

/// A built experiment world for one RM.
pub struct World {
    pub rm: RmConfig,
    pub cluster: Arc<Cluster>,
    pub catalog: Catalog,
    pub table: String,
    pub schema: Schema,
    /// The representative job's feature projection.
    pub projection: Vec<FeatureId>,
    /// Popularity stats over a month of simulated jobs (drives FR).
    pub stats: AccessStats,
}

/// Build a world: generate the dataset with `writer_opts`, sample a
/// representative projection, accumulate popularity stats.
pub fn build_world(
    rm: &RmConfig,
    scale: &SimScale,
    writer_opts: WriterOptions,
    seed: u64,
) -> Result<World> {
    let mut rng = Pcg32::new(seed);
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 4 << 20,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let handle =
        build_dataset(&cluster, &catalog, rm, scale, writer_opts, seed)?;
    let schema = handle.schema.clone();
    let stats = simulate_month(&mut rng.fork(1), rm, &schema, 90);
    let take = (schema.features.len() as f64 * rm.frac_feats_used())
        .round()
        .max(4.0) as usize;
    // §5.2: jobs "largely build upon a common baseline (e.g., the current
    // production model version)" — the representative job reads the
    // production feature set (the aggregate-popular features) plus a
    // smaller experimental tail sampled from the rest.
    let mut proj_rng = rng.fork(2);
    let baseline_n = take * 19 / 20;
    let order = stats.reorder();
    let mut projection: Vec<FeatureId> =
        order.iter().take(baseline_n).copied().collect();
    let rest: Vec<FeatureId> = order
        .iter()
        .skip(baseline_n)
        .copied()
        .collect();
    while projection.len() < take && projection.len() - baseline_n < rest.len() {
        let pick = rest[proj_rng.below(rest.len() as u64) as usize];
        if !projection.contains(&pick) {
            projection.push(pick);
        }
    }
    Ok(World {
        rm: rm.clone(),
        cluster,
        catalog,
        table: handle.table_name,
        schema,
        projection,
        stats,
    })
}

/// The popularity order for feature reordering, derived the way
/// production does it (§7.5: jobs launched within a recent window).
pub fn popularity_order(world: &World) -> Vec<FeatureId> {
    world.stats.reorder()
}

/// Rebuild the same world with different writer options (same seed so
/// data and projection distribution match).
pub fn rebuild(world: &World, scale: &SimScale, writer_opts: WriterOptions, seed: u64) -> Result<World> {
    build_world(&world.rm, scale, writer_opts, seed)
}

/// Result of a measured single-threaded pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineMeasurement {
    pub samples: u64,
    /// Worker wall seconds (busy; single thread).
    pub busy_secs: f64,
    /// Worker throughput, samples/s.
    pub worker_sps: f64,
    pub cost: PerSampleCost,
    pub storage: IoStats,
    /// Useful (wanted-stream) bytes fetched.
    pub storage_rx_bytes: u64,
    pub tensor_tx_bytes: u64,
    /// Storage throughput: delivered bytes per device-second (MB/s).
    pub storage_mbps: f64,
}

/// Run the real worker pipeline single-threaded over the whole dataset
/// with the given toggles; measure everything.
pub fn measure_pipeline(
    world: &World,
    pipeline: PipelineOptions,
    batch_size: usize,
    seed: u64,
) -> Result<PipelineMeasurement> {
    let mut rng = Pcg32::new(seed ^ 0xABCD);
    let dag = session_dag(&mut rng, &world.rm, &world.schema, &world.projection);
    let mut spec = SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, batch_size);
    // The projection includes DAG inputs; also read any projected raw
    // features not consumed by the DAG (loaded as-is in production).
    spec.projection = Projection::new(world.projection.iter().copied());
    spec.pipeline = pipeline;
    let spec = Arc::new(spec);

    world.cluster.reset_stats();
    let master = Master::new(&world.catalog, &world.cluster, (*spec).clone())?;
    let wid = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec, world.cluster.clone(), metrics.clone());
    while let Some(split) = master.fetch_split(wid) {
        let batches = core.process_split(&split)?;
        std::hint::black_box(&batches);
        master.complete_split(wid, split.id);
    }
    let storage = world.cluster.stats();
    let samples = metrics.samples.get();
    let busy = metrics.total_secs();
    let cost = PerSampleCost::from_metrics(&metrics);
    let storage_rx = metrics.storage_rx_bytes.get();
    Ok(PipelineMeasurement {
        samples,
        busy_secs: busy,
        worker_sps: samples as f64 / busy.max(1e-12),
        cost,
        storage_mbps: storage_rx as f64 / 1e6 / storage.device_secs.max(1e-12),
        storage,
        storage_rx_bytes: storage_rx,
        tensor_tx_bytes: metrics.tensor_tx_bytes.get(),
    })
}

/// Measure trainer-client loading cost per wire byte: decrypt +
/// deserialize a realistic tensor batch repeatedly.
pub fn measure_loading_cost_per_byte(seed: u64) -> f64 {
    use crate::dpp::TensorBatch;
    use crate::dwrf::crypto::StreamCipher;
    let mut rng = Pcg32::new(seed);
    // A representative DPP output batch.
    let rows = 64usize;
    let n_dense = 64usize;
    let dense: Vec<f32> = (0..rows * n_dense).map(|_| rng.f32()).collect();
    let mut sparse = Vec::new();
    for s in 0..16u32 {
        let mut offsets = vec![0u32];
        let mut ids = Vec::new();
        for _ in 0..rows {
            let n = rng.below(30) as usize;
            for _ in 0..n {
                ids.push(rng.below(1 << 20));
            }
            offsets.push(ids.len() as u32);
        }
        sparse.push((crate::schema::FeatureId(1000 + s), offsets, ids));
    }
    let tb = TensorBatch {
        rows,
        dense,
        dense_names: (0..n_dense as u32).map(crate::schema::FeatureId).collect(),
        sparse,
        labels: vec![0.5; rows],
    };
    let cipher = StreamCipher::for_table("loading-bench");
    let wire = tb.to_wire(&cipher, 7);
    let bytes = wire.len();
    // Warm + measure.
    let mut total = 0usize;
    let t = std::time::Instant::now();
    let iters = 64;
    for i in 0..iters {
        let got = TensorBatch::from_wire(&cipher, 7, &wire).unwrap();
        std::hint::black_box(&got);
        total += bytes;
        let _ = i;
    }
    t.elapsed().as_secs_f64() / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;

    #[test]
    fn world_builds_and_measures() {
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let world =
            build_world(&rm, &scale, WriterOptions::default(), 99).unwrap();
        assert!(!world.projection.is_empty());
        let m = measure_pipeline(&world, PipelineOptions::default(), 16, 1)
            .unwrap();
        assert_eq!(m.samples, 128);
        assert!(m.worker_sps > 0.0);
        assert!(m.storage_mbps > 0.0);
        assert!(m.cost.cpu_secs > 0.0);
        assert!(m.cost.frac_extract + m.cost.frac_transform + m.cost.frac_misc > 0.99);
    }

    #[test]
    fn loading_cost_is_positive_and_small() {
        let c = measure_loading_cost_per_byte(3);
        assert!(c > 0.0);
        assert!(c < 1e-5, "cost per byte {c}");
    }
}
