//! Integration: the AOT bridge. Loads the HLO-text artifacts produced by
//! `make artifacts`, compiles them on the PJRT CPU client, and verifies
//! numerics against expectations — the proof that L1 (Pallas) and L2
//! (JAX) compose with L3 (Rust) with no Python at runtime.

use dsi::runtime::{artifacts_available, artifacts_dir, DlrmBatch, DlrmRuntime};
use dsi::util::rng::Pcg32;

fn runtime() -> Option<DlrmRuntime> {
    if !artifacts_available() {
        eprintln!("skipping runtime integration: run `make artifacts`");
        return None;
    }
    Some(DlrmRuntime::load(&artifacts_dir()).expect("load artifacts"))
}

#[test]
fn dense_xform_kernel_matches_reference() {
    let Some(rt) = runtime() else { return };
    let m = &rt.manifest;
    let mut rng = Pcg32::new(1);
    let x: Vec<f32> = (0..m.batch * m.n_dense)
        .map(|_| rng.normal_ms(0.0, 3.0) as f32)
        .collect();
    let mean = vec![0f32; m.n_dense];
    let std = vec![2f32; m.n_dense];
    let y = rt.dense_xform(&x, &mean, &std).unwrap();
    assert_eq!(y.len(), x.len());
    for (i, (&xi, &yi)) in x.iter().zip(y.iter()).enumerate() {
        let z = (xi - 0.0) / 2.0;
        let want = (z.signum() * z.abs().ln_1p()).clamp(-8.0, 8.0);
        assert!(
            (yi - want).abs() < 1e-5,
            "elem {i}: kernel {yi} vs ref {want}"
        );
    }
}

#[test]
fn fwd_loss_is_finite_and_reasonable() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params(7).unwrap();
    let mut rng = Pcg32::new(2);
    let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
    let (loss, logits) = rt.fwd_loss(&params, &batch).unwrap();
    assert!(loss.is_finite());
    // Untrained BCE should hover near ln 2.
    assert!((0.2..2.0).contains(&loss), "loss {loss}");
    assert_eq!(logits.len(), rt.manifest.batch);
    assert!(logits.iter().all(|l| l.is_finite()));
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let mut params = rt.init_params(7).unwrap();
    let mut rng = Pcg32::new(3);
    let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
    let (loss0, _) = rt.fwd_loss(&params, &batch).unwrap();
    let mut last = loss0;
    for _ in 0..30 {
        let (p, l) = rt.train_step(params, &batch).unwrap();
        params = p;
        last = l;
    }
    assert!(
        last < loss0 * 0.9,
        "loss did not drop: {loss0} -> {last}"
    );
}

#[test]
fn training_loss_curve_descends_across_batches() {
    let Some(rt) = runtime() else { return };
    let mut params = rt.init_params(11).unwrap();
    let mut rng = Pcg32::new(5);
    // Learnable task (labels depend on dense feature 0): loss must fall
    // across *different* batches, i.e. the model generalizes.
    let mut first5 = 0.0;
    let mut last5 = 0.0;
    let steps = 100;
    for step in 0..steps {
        let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
        let (p, loss) = rt.train_step(params, &batch).unwrap();
        params = p;
        if step < 5 {
            first5 += loss;
        }
        if step >= steps - 5 {
            last5 += loss;
        }
    }
    assert!(
        last5 < first5 * 0.9,
        "no learning: first5 {first5} last5 {last5}"
    );
}

#[test]
fn params_stay_finite_through_training() {
    let Some(rt) = runtime() else { return };
    let mut params = rt.init_params(13).unwrap();
    let mut rng = Pcg32::new(17);
    for _ in 0..10 {
        let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
        let (p, loss) = rt.train_step(params, &batch).unwrap();
        assert!(loss.is_finite());
        params = p;
    }
    for (i, p) in params.iter().enumerate() {
        let v = p.to_vec::<f32>().unwrap();
        assert!(
            v.iter().all(|x| x.is_finite()),
            "param {i} has non-finite values"
        );
    }
}
