//! End-to-end filter pushdown: for every predicate kind (and their
//! conjunction), the pushed-down pipeline — stripe-stat pruning +
//! selection-vector batches — must deliver exactly the rows the
//! decode-then-filter baseline delivers, on Flattened *and* Dedup
//! encodings, while reading and decoding strictly less.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, TensorBatch, WorkerCore};
use dsi::dwrf::crypto::StreamCipher;
use dsi::dwrf::{Encoding, WriterOptions};
use dsi::filter::RowPredicate;
use dsi::metrics::EtlMetrics;
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::warehouse::Catalog;
use std::sync::Arc;

const SEED: u64 = 31;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    table: String,
    spec: SessionSpec,
    total_rows: u64,
    /// A sparse feature with partial coverage, for FeaturePresent.
    partial_feature: FeatureId,
}

fn build(encoding: Encoding) -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 512,
        materialized_features: 64,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 64,
            ..Default::default()
        },
        SEED,
        &GenOptions {
            dup_factor: if encoding == Encoding::Dedup { 4 } else { 1 },
            tick_max: 30,
            ..Default::default()
        },
    )
    .unwrap();
    let mut dag = TransformDag::default();
    let picked: Vec<&dsi::schema::FeatureDef> = h
        .schema
        .dense()
        .take(4)
        .chain(h.schema.sparse().take(6))
        .collect();
    for f in &picked {
        match f.kind {
            FeatureKind::Dense => {
                let i = dag.input_dense(f.id);
                let c = dag.apply(Op::Clamp { lo: -4.0, hi: 4.0 }, vec![i]);
                dag.output(f.id, c);
            }
            _ => {
                let i = dag.input_sparse(f.id);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 5,
                        modulus: 1 << 14,
                    },
                    vec![i],
                );
                dag.output(f.id, s);
            }
        }
    }
    // A projected sparse feature with < 100% coverage: some rows have
    // it, some do not — exactly what FeaturePresent filters on.
    let partial_feature = picked
        .iter()
        .filter(|f| !matches!(f.kind, FeatureKind::Dense))
        .min_by(|a, b| a.coverage.total_cmp(&b.coverage))
        .map(|f| f.id)
        .unwrap();
    let spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 32);
    let t = catalog.get(&h.table_name).unwrap();
    World {
        cluster,
        catalog,
        table: h.table_name,
        spec,
        total_rows: t.total_rows(),
        partial_feature,
    }
}

/// Canonical, orderable form of one tensor row (bitwise floats).
type RowKey = (u32, Vec<u32>, Vec<(u32, Vec<u64>)>);

fn row_keys(tb: &TensorBatch) -> Vec<RowKey> {
    let d = tb.dense_names.len();
    (0..tb.rows)
        .map(|r| {
            let dense: Vec<u32> = tb.dense[r * d..(r + 1) * d]
                .iter()
                .map(|v| v.to_bits())
                .collect();
            let sparse: Vec<(u32, Vec<u64>)> = tb
                .sparse
                .iter()
                .map(|(f, offsets, ids)| {
                    (
                        f.0,
                        ids[offsets[r] as usize..offsets[r + 1] as usize]
                            .to_vec(),
                    )
                })
                .collect();
            (tb.labels[r].to_bits(), dense, sparse)
        })
        .collect()
}

/// Drain a single-threaded worker over the session; return the sorted
/// multiset of delivered rows and the metrics.
fn drain(
    world: &World,
    predicate: RowPredicate,
    pushdown: bool,
) -> (Vec<RowKey>, Arc<EtlMetrics>, usize) {
    let mut spec = world.spec.clone().with_predicate(predicate);
    spec.pipeline.pushdown = pushdown;
    let spec = Arc::new(spec);
    let master =
        Master::new(&world.catalog, &world.cluster, (*spec).clone()).unwrap();
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(spec.clone(), world.cluster.clone(), metrics.clone());
    let cipher = StreamCipher::for_table(&world.table);
    let mut rows = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for wire in core.process_split(&split).unwrap() {
            let tb = if wire.dedup {
                dsi::dpp::codec::decode_wire_dedup(&cipher, &wire)
                    .unwrap()
                    .expand()
            } else {
                dsi::dpp::codec::decode_wire(&cipher, &wire).unwrap()
            };
            assert_eq!(tb.rows, wire.rows);
            rows.extend(row_keys(&tb));
        }
        master.complete_split(w, split.id);
    }
    rows.sort();
    (rows, metrics, master.skipped_splits())
}

fn predicates(world: &World) -> Vec<(&'static str, RowPredicate)> {
    // Timestamps span [day_epoch, ...]; day 0 rows sit in roughly
    // [1, 512 * 15]; pick a window cutting through the middle of day 0
    // and all of day 1.
    vec![
        (
            "timestamp-range",
            RowPredicate::TimestampRange {
                min: 2_000,
                max: u64::MAX,
            },
        ),
        (
            "negative-downsample",
            RowPredicate::NegativeDownsample {
                rate: 0.25,
                seed: 7,
            },
        ),
        (
            "feature-present",
            RowPredicate::FeaturePresent {
                feature: world.partial_feature,
            },
        ),
        (
            "sample-rate",
            RowPredicate::SampleRate { rate: 0.3, seed: 11 },
        ),
        (
            "conjunction",
            RowPredicate::And(vec![
                RowPredicate::TimestampRange {
                    min: 0,
                    max: 86_400 + 3_000,
                },
                RowPredicate::NegativeDownsample {
                    rate: 0.5,
                    seed: 3,
                },
            ]),
        ),
    ]
}

#[test]
fn pushdown_is_lossless_for_every_predicate_on_flattened() {
    let world = build(Encoding::Flattened);
    for (name, pred) in predicates(&world) {
        let (base_rows, base_m, _) = drain(&world, pred.clone(), false);
        let (push_rows, push_m, _) = drain(&world, pred, true);
        assert_eq!(
            base_rows, push_rows,
            "{name}: pushdown must deliver exactly the baseline rows"
        );
        assert!(
            !base_rows.is_empty() && base_rows.len() < world.total_rows as usize,
            "{name}: predicate should be partially selective \
             (kept {} of {})",
            base_rows.len(),
            world.total_rows
        );
        // Pushdown never decodes more than the baseline.
        assert!(
            push_m.decoded_rows.get() <= base_m.decoded_rows.get(),
            "{name}: decoded {} > baseline {}",
            push_m.decoded_rows.get(),
            base_m.decoded_rows.get()
        );
        assert!(
            push_m.storage_rx_bytes.get() <= base_m.storage_rx_bytes.get(),
            "{name}: pushdown read more bytes than baseline"
        );
    }
}

#[test]
fn pushdown_is_lossless_on_dedup_encoding() {
    let world = build(Encoding::Dedup);
    for (name, pred) in predicates(&world) {
        let (base_rows, _, _) = drain(&world, pred.clone(), false);
        let (push_rows, push_m, _) = drain(&world, pred, true);
        assert_eq!(base_rows, push_rows, "{name}: dedup pushdown lossless");
        // The dedup-aware path stayed active (content-keyed predicates
        // never force the oblivious fallback).
        assert!(
            push_m.transform_rows.get() <= push_m.decoded_rows.get(),
            "{name}: transforms ran per unique payload"
        );
    }
}

#[test]
fn timestamp_pushdown_skips_stripes_and_bytes() {
    let world = build(Encoding::Flattened);
    // Day 1 only: every day-0 stripe is provably out of range.
    let pred = RowPredicate::TimestampRange {
        min: 86_400,
        max: u64::MAX,
    };
    let (base_rows, base_m, _) = drain(&world, pred.clone(), false);
    let (push_rows, push_m, skipped_splits) = drain(&world, pred, true);
    assert_eq!(base_rows, push_rows);
    assert_eq!(push_rows.len() as u64, world.total_rows / 2);
    // The whole day-0 file never became splits (or its stripes were
    // skipped in-plan); either way the worker decoded only day 1.
    assert!(
        skipped_splits > 0 || push_m.skipped_stripes.get() > 0,
        "something must have been pruned"
    );
    assert_eq!(push_m.decoded_rows.get(), world.total_rows / 2);
    assert_eq!(base_m.decoded_rows.get(), world.total_rows);
    assert!(push_m.storage_rx_bytes.get() * 3 < base_m.storage_rx_bytes.get() * 2);
    assert_eq!(push_m.filtered_rows.get(), 0, "no partial stripes here");
}

#[test]
fn fully_filtered_session_issues_zero_data_ios() {
    let world = build(Encoding::Flattened);
    let pred = RowPredicate::TimestampRange {
        min: u64::MAX - 1,
        max: u64::MAX,
    };
    let (rows, m, skipped_splits) = drain(&world, pred, true);
    assert!(rows.is_empty());
    assert_eq!(m.storage_rx_bytes.get(), 0, "zero I/Os for pruned stripes");
    assert_eq!(m.decoded_rows.get(), 0);
    assert!(skipped_splits > 0);
}

#[test]
fn selection_metrics_account_for_filtered_rows() {
    let world = build(Encoding::Flattened);
    let pred = RowPredicate::SampleRate { rate: 0.5, seed: 2 };
    let (rows, m, _) = drain(&world, pred, true);
    // SampleRate cannot prune stripes (it needs per-row hashes), so
    // everything decodes and the selection vector drops the rest.
    assert_eq!(m.decoded_rows.get(), world.total_rows);
    assert_eq!(
        m.filtered_rows.get() as usize,
        world.total_rows as usize - rows.len()
    );
    assert_eq!(m.skipped_stripes.get(), 0);
    assert!(m.observed_selectivity() > 0.3 && m.observed_selectivity() < 0.7);
}
