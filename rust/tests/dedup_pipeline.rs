//! End-to-end integration of the dedup subsystem: a duplicated
//! warehouse written as DedupDWRF, preprocessed by the dedup-aware DPP
//! path, and expanded on the client must deliver exactly the tensors of
//! the duplication-oblivious flattened path — while storing, reading,
//! and transforming a fraction of the bytes/rows.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset_dup;
use dsi::dedup::scan_table;
use dsi::dpp::{
    Master, Session, SessionConfig, SessionSpec, TensorBatch, WorkerCore,
};
use dsi::dwrf::crypto::StreamCipher;
use dsi::dwrf::{
    DecodeMode, DwrfReader, Encoding, IoRange, Projection, WriterOptions,
};
use dsi::metrics::EtlMetrics;
use dsi::schema::FeatureKind;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::warehouse::Catalog;
use std::sync::Arc;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    table: String,
    spec: SessionSpec,
    total_rows: u64,
    stored_bytes: u64,
}

const SEED: u64 = 23;
const DUP: usize = 4;

fn build(encoding: Encoding) -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 512,
        materialized_features: 64,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_dup(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 64,
            ..Default::default()
        },
        SEED,
        DUP,
    )
    .unwrap();
    // Deterministic session: normalization over a dense + sparse mix
    // (sparse lists carry most of the payload bytes, as in production).
    let mut dag = TransformDag::default();
    let picked: Vec<&dsi::schema::FeatureDef> = h
        .schema
        .dense()
        .take(4)
        .chain(h.schema.sparse().take(8))
        .collect();
    for f in picked {
        match f.kind {
            FeatureKind::Dense => {
                let i = dag.input_dense(f.id);
                let c =
                    dag.apply(Op::Clamp { lo: -4.0, hi: 4.0 }, vec![i]);
                dag.output(f.id, c);
            }
            _ => {
                let i = dag.input_sparse(f.id);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 5,
                        modulus: 1 << 14,
                    },
                    vec![i],
                );
                dag.output(f.id, s);
            }
        }
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 32);
    let t = catalog.get(&h.table_name).unwrap();
    World {
        cluster,
        catalog,
        table: h.table_name,
        spec,
        total_rows: t.total_rows(),
        stored_bytes: t.total_bytes(),
    }
}

/// Canonical, orderable form of one tensor row (bitwise floats).
type RowKey = (u32, Vec<u32>, Vec<(u32, Vec<u64>)>);

fn row_keys(tb: &TensorBatch) -> Vec<RowKey> {
    let d = tb.dense_names.len();
    (0..tb.rows)
        .map(|r| {
            let dense: Vec<u32> = tb.dense[r * d..(r + 1) * d]
                .iter()
                .map(|v| v.to_bits())
                .collect();
            let sparse: Vec<(u32, Vec<u64>)> = tb
                .sparse
                .iter()
                .map(|(f, offsets, ids)| {
                    (
                        f.0,
                        ids[offsets[r] as usize..offsets[r + 1] as usize]
                            .to_vec(),
                    )
                })
                .collect();
            (tb.labels[r].to_bits(), dense, sparse)
        })
        .collect()
}

/// Run a single-threaded worker over the whole session; return decoded
/// tensor batches (dedup wires expanded) and the metrics.
fn drain(world: &World, dedup_aware: bool) -> (Vec<TensorBatch>, Arc<EtlMetrics>) {
    let mut spec = world.spec.clone();
    spec.pipeline.dedup_aware = dedup_aware;
    let spec = Arc::new(spec);
    let master =
        Master::new(&world.catalog, &world.cluster, (*spec).clone()).unwrap();
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(spec.clone(), world.cluster.clone(), metrics.clone());
    world.cluster.reset_stats();
    let cipher = StreamCipher::for_table(&world.table);
    let mut out = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for wire in core.process_split(&split).unwrap() {
            let tb = if wire.dedup {
                let db =
                    dsi::dpp::codec::decode_wire_dedup(&cipher, &wire).unwrap();
                assert_eq!(db.rows(), wire.rows);
                db.expand()
            } else {
                dsi::dpp::codec::decode_wire(&cipher, &wire).unwrap()
            };
            assert_eq!(tb.rows, wire.rows);
            out.push(tb);
        }
        master.complete_split(w, split.id);
    }
    (out, metrics)
}

#[test]
fn warehouse_scan_sees_the_injected_duplication() {
    let flat = build(Encoding::Flattened);
    let rep = scan_table(&flat.cluster, &flat.catalog, &flat.table).unwrap();
    assert_eq!(rep.global.rows, flat.total_rows);
    assert!(
        rep.within_partition().factor() > 2.0,
        "observed factor {}",
        rep.within_partition().factor()
    );
}

#[test]
fn dedup_file_roundtrips_the_same_sample_multiset() {
    let flat = build(Encoding::Flattened);
    let dedup = build(Encoding::Dedup);
    let read_world = |w: &World| {
        let t = w.catalog.get(&w.table).unwrap();
        let proj =
            Projection::new(t.schema.features.iter().map(|f| f.id));
        let mut rows = Vec::new();
        for p in &t.partitions {
            let len = w.cluster.file_len(p.file).unwrap();
            let bytes = w
                .cluster
                .read_range(p.file, IoRange { offset: 0, len })
                .unwrap();
            let r = DwrfReader::open_table(&bytes, &w.table).unwrap();
            let plan = r.plan(&proj, None);
            let bufs = r.fetch_local(&bytes, &plan);
            for s in 0..r.meta.stripes.len() {
                rows.extend(
                    r.decode_stripe_rows(
                        s,
                        &bufs,
                        &proj,
                        DecodeMode::default(),
                    )
                    .unwrap(),
                );
            }
        }
        // Serving timestamps are strictly increasing → canonical order.
        rows.sort_by_key(|s| s.timestamp);
        rows
    };
    assert_eq!(read_world(&flat), read_world(&dedup));
}

#[test]
fn dedup_aware_worker_delivers_identical_tensors() {
    let flat = build(Encoding::Flattened);
    let dedup = build(Encoding::Dedup);
    let (flat_batches, flat_m) = drain(&flat, false);
    let (dedup_batches, dedup_m) = drain(&dedup, true);
    let rows = |bs: &[TensorBatch]| -> usize {
        bs.iter().map(|b| b.rows).sum()
    };
    assert_eq!(rows(&flat_batches) as u64, flat.total_rows);
    assert_eq!(rows(&dedup_batches) as u64, dedup.total_rows);
    // Same multiset of fully-preprocessed rows on both paths.
    let mut a: Vec<RowKey> =
        flat_batches.iter().flat_map(|b| row_keys(b)).collect();
    let mut b: Vec<RowKey> =
        dedup_batches.iter().flat_map(|b| row_keys(b)).collect();
    a.sort();
    b.sort();
    assert_eq!(a, b);
    // And the dedup path did strictly less transform work.
    assert!(dedup_m.transform_rows.get() < flat_m.transform_rows.get());
    assert!(dedup_m.dedup_saved_rows.get() > 0);
    assert_eq!(flat_m.dedup_saved_rows.get(), 0);
}

#[test]
fn oblivious_worker_on_dedup_file_matches_dedup_aware_exactly() {
    let world = build(Encoding::Dedup);
    let (aware, aware_m) = drain(&world, true);
    let (oblivious, oblivious_m) = drain(&world, false);
    // Same file, same split order → batch-for-batch identical tensors.
    assert_eq!(aware, oblivious);
    assert!(aware_m.transform_rows.get() < oblivious_m.transform_rows.get());
}

#[test]
fn row_index_sensitive_dag_on_dedup_stripes_falls_back_losslessly() {
    // A DAG containing the legacy `Sampling` op (position-hash keep
    // mask) is row-index-sensitive: evaluating it over unique payloads
    // would be unsound, so the dedup-aware worker must silently fall
    // back to the oblivious path — and produce *identical* output to a
    // worker with dedup awareness disabled.
    let mut world = build(Encoding::Dedup);
    let fid = *world
        .spec
        .projection
        .iter()
        .min_by_key(|f| f.0)
        .expect("projected feature");
    let mut dag = world.spec.dag.clone();
    let i = dag.input(fid);
    let mask = dag.apply(Op::Sampling { rate: 0.5, seed: 9 }, vec![i]);
    dag.output(dsi::schema::FeatureId(999_999), mask);
    assert!(dag.row_index_sensitive());
    world.spec.dag = dag;

    let (aware, aware_m) = drain(&world, true);
    let (oblivious, oblivious_m) = drain(&world, false);
    // Same file, same split order → batch-for-batch identical tensors.
    assert_eq!(aware, oblivious);
    let rows: usize = aware.iter().map(|b| b.rows).sum();
    assert_eq!(rows as u64, world.total_rows);
    // Fallback really engaged: no dedup savings on either side.
    assert_eq!(aware_m.transform_rows.get(), oblivious_m.transform_rows.get());
    assert_eq!(aware_m.dedup_saved_rows.get(), 0);
}

#[test]
fn dedup_halves_storage_read_and_preproc_at_factor_4() {
    let flat = build(Encoding::Flattened);
    let dedup = build(Encoding::Dedup);
    assert!(
        dedup.stored_bytes * 2 <= flat.stored_bytes,
        "stored: dedup {} vs flat {}",
        dedup.stored_bytes,
        flat.stored_bytes
    );
    let (_, flat_m) = drain(&flat, false);
    let (_, dedup_m) = drain(&dedup, true);
    assert!(
        dedup_m.transform_rows.get() * 2 <= flat_m.transform_rows.get(),
        "preproc rows: dedup {} vs flat {}",
        dedup_m.transform_rows.get(),
        flat_m.transform_rows.get()
    );
    assert!(
        dedup_m.storage_rx_bytes.get() * 2 <= flat_m.storage_rx_bytes.get(),
        "read bytes: dedup {} vs flat {}",
        dedup_m.storage_rx_bytes.get(),
        flat_m.storage_rx_bytes.get()
    );
    assert!(
        dedup_m.tensor_tx_bytes.get() < flat_m.tensor_tx_bytes.get(),
        "wire bytes should shrink too"
    );
}

#[test]
fn threaded_session_over_dedup_dataset_delivers_every_row() {
    let world = build(Encoding::Dedup);
    let report = Session::run(
        &world.catalog,
        &world.cluster,
        world.spec.clone(),
        &SessionConfig {
            initial_workers: 2,
            max_workers: 4,
            clients: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.rows_delivered, world.total_rows);
    assert!(report.client_rx_bytes > 0);
}
