//! End-to-end pipeline integration: offline generation → warehouse →
//! DWRF/Tectonic → DPP session → tensors, exercising every subsystem
//! together under the standard production configuration.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::{PipelineOptions, Session, SessionConfig, SessionSpec};
use dsi::dwrf::{Encoding, Projection, WriterOptions};
use dsi::schema::FeatureKind;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;

struct WorldFixture {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    table: String,
    spec: SessionSpec,
    total_rows: u64,
}

fn build(rm_id: RmId, encoding: Encoding, seed: u64) -> WorldFixture {
    let rm = RmConfig::get(rm_id);
    let scale = SimScale {
        rows_per_partition: 256,
        materialized_features: 64,
        partitions: 3,
    };
    let mut rng = Pcg32::new(seed);
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let handle = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 64,
            ..Default::default()
        },
        seed,
    )
    .unwrap();
    let take = (handle.schema.features.len() as f64 * rm.frac_feats_used())
        .round()
        .max(6.0) as usize;
    let projection =
        handle
            .schema
            .sample_projection(&mut rng, take, rm.popularity_zipf_s);
    let dag = session_dag(&mut rng, &rm, &handle.schema, &projection);
    let mut spec = SessionSpec::from_dag(&handle.table_name, 0, u32::MAX, dag, 32);
    spec.projection = Projection::new(projection);
    let total_rows = catalog.get(&handle.table_name).unwrap().total_rows();
    WorldFixture {
        cluster,
        catalog,
        table: handle.table_name,
        spec,
        total_rows,
    }
}

#[test]
fn full_pipeline_flattened_encoding() {
    let w = build(RmId::Rm1, Encoding::Flattened, 1);
    let report = Session::run(
        &w.catalog,
        &w.cluster,
        w.spec.clone(),
        &SessionConfig {
            initial_workers: 3,
            max_workers: 3,
            clients: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.rows_delivered, w.total_rows);
    assert!(report.storage_reads > 0);
    assert!(report.client_rx_bytes > 0);
    assert!(report.tensor_tx_bytes >= report.client_rx_bytes);
}

#[test]
fn full_pipeline_map_encoding_baseline() {
    let w = build(RmId::Rm2, Encoding::Map, 2);
    let mut spec = w.spec.clone();
    spec.pipeline = PipelineOptions::baseline();
    let report = Session::run(
        &w.catalog,
        &w.cluster,
        spec,
        &SessionConfig::default(),
    )
    .unwrap();
    assert_eq!(report.rows_delivered, w.total_rows);
}

#[test]
fn pipeline_variants_agree_on_row_count() {
    // Every PipelineOptions combination must deliver exactly the dataset.
    let w = build(RmId::Rm3, Encoding::Flattened, 3);
    for coalesce in [None, Some(1u64 << 20)] {
        for fast in [false, true] {
            for flatmap in [false, true] {
                let mut spec = w.spec.clone();
                spec.pipeline = PipelineOptions {
                    coalesce,
                    fast_decode: fast,
                    flatmap,
                    ..PipelineOptions::baseline()
                };
                let report = Session::run(
                    &w.catalog,
                    &w.cluster,
                    spec,
                    &SessionConfig::default(),
                )
                .unwrap();
                assert_eq!(
                    report.rows_delivered, w.total_rows,
                    "coalesce={coalesce:?} fast={fast} flatmap={flatmap}"
                );
            }
        }
    }
}

#[test]
fn replication_survives_dataset_build() {
    let w = build(RmId::Rm3, Encoding::Flattened, 4);
    assert_eq!(
        w.cluster.stored_bytes(),
        3 * w.cluster.logical_bytes(),
        "triplicate replication"
    );
}

#[test]
fn labels_flow_through_to_tensors() {
    // The CTR labels produced by the ETL join must arrive in tensors with
    // a plausible positive rate.
    use dsi::dpp::{Master, WorkerCore};
    use dsi::metrics::EtlMetrics;
    let w = build(RmId::Rm1, Encoding::Flattened, 5);
    let spec = Arc::new(w.spec.clone());
    let master = Master::new(&w.catalog, &w.cluster, (*spec).clone()).unwrap();
    let id = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec.clone(), w.cluster.clone(), metrics);
    let cipher = dsi::dwrf::crypto::StreamCipher::for_table(&w.table);
    let mut pos = 0usize;
    let mut total = 0usize;
    while let Some(split) = master.fetch_split(id) {
        for wire in core.process_split(&split).unwrap() {
            let tb = dsi::dpp::codec::decode_wire(&cipher, &wire).unwrap();
            pos += tb.labels.iter().filter(|&&l| l == 1.0).count();
            total += tb.labels.len();
            assert!(tb.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        }
        master.complete_split(id, split.id);
    }
    assert_eq!(total as u64, w.total_rows);
    let rate = pos as f64 / total as f64;
    assert!(
        (0.02..0.4).contains(&rate),
        "CTR-like positive rate, got {rate}"
    );
}
