//! End-to-end observability: two broker-attached sessions tracing into
//! one shared [`Obs`] timeline, span coverage for every completed
//! split, stall-attribution reconciliation, and the Chrome trace JSON
//! export round-tripping through `util::json`.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

use dsi::broker::ReadBroker;
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::{
    run_session_on, Master, Session, SessionConfig, SessionSpec,
};
use dsi::dwrf::{Projection, WriterOptions};
use dsi::obs::{Obs, Stage};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;

/// Client trace lanes start here (worker lanes are pool slots from 0).
const CLIENT_TID_BASE: u32 = 1000;
/// Broker fetch lane (`u32::MAX` is the Master's control-plane lane).
const BROKER_LANE: u32 = u32::MAX - 1;

fn fixture(seed: u64) -> (Arc<Cluster>, Catalog, SessionSpec, u64) {
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale {
        rows_per_partition: 192,
        materialized_features: 48,
        partitions: 4,
    };
    let mut rng = Pcg32::new(seed);
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 48,
            ..Default::default()
        },
        seed,
    )
    .unwrap();
    let projection = h.schema.sample_projection(&mut rng, 10, 1.0);
    let dag = session_dag(&mut rng, &rm, &h.schema, &projection);
    let mut spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 24);
    spec.projection = Projection::new(projection);
    let rows = catalog.get(&h.table_name).unwrap().total_rows();
    (cluster, catalog, spec, rows)
}

#[test]
fn two_traced_sessions_share_one_timeline() {
    let (cluster, catalog, mut spec, rows) = fixture(71);
    spec.pipeline.shared_reads = true;
    let broker = ReadBroker::with_budget_bytes(cluster.clone(), 256 << 20);
    let obs = Obs::new();
    let cfg = SessionConfig {
        initial_workers: 2,
        max_workers: 2,
        clients: 1,
        obs: Some(obs.clone()),
        telemetry_every: Some(Duration::from_millis(2)),
        ..Default::default()
    };

    let mut expected_splits = Vec::new();
    let mut reports = Vec::new();
    for _ in 0..2 {
        let master = Arc::new(
            Master::new_shared(&catalog, &cluster, spec.clone(), &broker)
                .unwrap(),
        );
        let report = run_session_on(master.clone(), &cluster, &cfg).unwrap();
        assert_eq!(report.rows_delivered, rows);
        let (done, total) = master.progress();
        assert_eq!(done, total);
        // Enumeration-pruned splits never reach a worker, so they
        // never produce data-plane spans.
        expected_splits.push(total - master.skipped_splits());
        reports.push(report);
    }

    let events = obs.trace.events();
    assert_eq!(obs.trace.dropped(), 0, "ring buffer overflowed");
    for pid in 0..2u32 {
        let mine: Vec<_> =
            events.iter().filter(|e| e.session == pid).collect();
        // Worker lanes: every completed split carries the full
        // per-split stage ladder, including the backpressured send.
        let mut by_split: HashMap<u64, HashSet<&'static str>> =
            HashMap::new();
        for e in mine.iter().filter(|e| e.tid < CLIENT_TID_BASE) {
            by_split.entry(e.split).or_default().insert(e.stage.name());
        }
        assert_eq!(
            by_split.len(),
            expected_splits[pid as usize],
            "session {pid}: traced splits"
        );
        for (split, stages) in &by_split {
            for want in
                ["plan", "fetch", "decode", "transform", "load", "wire_send"]
            {
                assert!(
                    stages.contains(want),
                    "session {pid} split {split} missing {want} span"
                );
            }
        }
        // The Master's control-plane planning span.
        assert!(
            mine.iter()
                .any(|e| e.tid == u32::MAX && e.stage == Stage::Plan),
            "session {pid} missing master plan span"
        );
        // Client lanes drain the stream.
        let clients: Vec<_> = mine
            .iter()
            .filter(|e| e.tid >= CLIENT_TID_BASE && e.tid < BROKER_LANE)
            .collect();
        assert!(
            clients.iter().any(|e| e.stage == Stage::WireRecv),
            "session {pid} missing wire_recv span"
        );
        assert!(
            clients.iter().any(|e| e.stage == Stage::Drain),
            "session {pid} missing drain span"
        );
    }
    // The cold session's storage reads flow through the broker lane.
    assert!(
        events
            .iter()
            .any(|e| e.tid == BROKER_LANE && e.stage == Stage::Fetch),
        "no broker fetch spans"
    );

    // Stall attribution reconciles for both sessions (the ISSUE's ±1%
    // acceptance bar), and telemetry sampled something.
    for (i, r) in reports.iter().enumerate() {
        let total = r.stall_attribution.total();
        assert!(
            (total - r.client_stall_secs).abs()
                <= 0.01 * r.client_stall_secs + 1e-6,
            "session {i}: attribution {total} vs stall {}",
            r.client_stall_secs
        );
        let tel = r.telemetry.as_ref().expect("telemetry enabled");
        assert!(tel.samples() > 0, "session {i}: no samples");
    }
    // Shared-stage histograms cover both sessions' stage ladder.
    for stage in [Stage::Fetch, Stage::Decode, Stage::Transform, Stage::Load]
    {
        assert!(
            obs.hist(stage).count() >= 2 * expected_splits[0] as u64,
            "{} histogram undercounts",
            stage.name()
        );
    }
}

#[test]
fn chrome_trace_export_roundtrips_through_util_json() {
    let (cluster, catalog, mut spec, rows) = fixture(72);
    spec.pipeline.tracing = true;
    let report = Session::run(
        &catalog,
        &cluster,
        spec,
        &SessionConfig {
            initial_workers: 2,
            max_workers: 2,
            clients: 1,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.rows_delivered, rows);
    let obs = report.obs.as_ref().expect("traced session has a sink");

    let text = obs.chrome_trace().to_string_pretty();
    let parsed = Json::parse(&text).expect("trace JSON parses");
    assert_eq!(
        parsed.get("displayTimeUnit").and_then(|u| u.as_str()),
        Some("ms")
    );
    let evs = parsed
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    let metas: Vec<_> = evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
        .collect();
    assert_eq!(metas.len(), 1, "one session registered");
    assert_eq!(metas[0].get("pid").and_then(|p| p.as_f64()), Some(0.0));
    let spans = evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .count();
    assert_eq!(spans, obs.trace.len(), "every span exported");
    assert!(spans > 0);
    // Every span has positive duration and a split label in its args.
    for ev in evs
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
    {
        assert!(ev.get("dur").and_then(|d| d.as_f64()).unwrap() > 0.0);
        assert!(ev.get("args").and_then(|a| a.get("split")).is_some());
    }

    // util::json round-trip: parse(serialize(parsed)) == parsed.
    let again = Json::parse(&parsed.to_string_pretty()).unwrap();
    assert_eq!(again, parsed);
}
