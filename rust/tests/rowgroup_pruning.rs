//! Row-group zone maps, end to end: sub-stripe pruning must cut decoded
//! rows with **byte-identical** client output vs stripe-only pruning, on
//! the private and broker read paths and on both Flattened and Dedup
//! encodings; v2 (pre-row-group) files must keep reading via the
//! stats-less fallback; corrupt and oversized footers must error / read
//! correctly instead of panicking.

use dsi::broker::ReadBroker;
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::{
    DecodeMode, DwrfReader, DwrfWriter, Encoding, Projection, WriterOptions,
};
use dsi::filter::RowPredicate;
use dsi::metrics::EtlMetrics;
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig, FileId};
use dsi::transforms::{Op, TransformDag};
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;

const SEED: u64 = 47;

/// One wire batch as shipped to the client: (seq, rows, dedup, bytes).
type WireRecord = (u64, usize, bool, Vec<u8>);

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
    total_rows: u64,
}

/// A dataset whose stripes are wide (256 rows) but whose zone maps are
/// fine (32-row groups): recency windows prune most of a stripe's
/// groups while the stripe itself survives.
fn build(encoding: Encoding) -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 1024,
        materialized_features: 48,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 256,
            rows_per_group: 32,
            ..Default::default()
        },
        SEED,
        &GenOptions {
            // Even the Dedup world keeps dup_factor 1 here: the
            // generator scatters a session's duplicates across the
            // whole partition, so after clustering every row group
            // spans the full day and timestamp zone maps (correctly)
            // prune nothing. Locally-duplicated data — where Dedup
            // group pruning does bite — is covered by
            // `prop_row_group_pruning_is_sound_and_lossless`.
            dup_factor: 1,
            tick_max: 30,
            ..Default::default()
        },
    )
    .unwrap();
    let mut dag = TransformDag::default();
    let picked: Vec<&dsi::schema::FeatureDef> = h
        .schema
        .dense()
        .take(3)
        .chain(h.schema.sparse().take(4))
        .collect();
    for f in &picked {
        match f.kind {
            FeatureKind::Dense => {
                let i = dag.input_dense(f.id);
                let c = dag.apply(Op::Clamp { lo: -4.0, hi: 4.0 }, vec![i]);
                dag.output(f.id, c);
            }
            _ => {
                let i = dag.input_sparse(f.id);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 5,
                        modulus: 1 << 14,
                    },
                    vec![i],
                );
                dag.output(f.id, s);
            }
        }
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 32);
    let t = catalog.get(&h.table_name).unwrap();
    World {
        cluster,
        catalog,
        spec,
        total_rows: t.total_rows(),
    }
}

/// Run one single-worker session; return the raw wire batches and
/// metrics. `row_groups = false` limits pushdown to stripe granularity.
fn run(
    world: &World,
    predicate: RowPredicate,
    pushdown: bool,
    row_groups: bool,
) -> (Vec<WireRecord>, Arc<EtlMetrics>) {
    let mut spec = world.spec.clone().with_predicate(predicate);
    spec.pipeline.pushdown = pushdown;
    spec.pipeline.row_group_pruning = row_groups;
    // No read coalescing: the byte assertions below compare exactly the
    // planned stream extents (the default 1.25 MiB window would absorb
    // a pruned group's gap as over-read at this scale and mask the
    // saving).
    spec.pipeline.coalesce = None;
    let spec = Arc::new(spec);
    let master =
        Master::new(&world.catalog, &world.cluster, (*spec).clone()).unwrap();
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(spec, world.cluster.clone(), metrics.clone());
    let mut wire = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for b in core.process_split(&split).unwrap() {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        master.complete_split(w, split.id);
    }
    (wire, metrics)
}

/// A recency window over roughly the newest `frac` of day-0 rows (day 1
/// prunes whole; day 0 prunes per group).
fn narrow_window(frac: f64) -> RowPredicate {
    // Day 0 timestamps: ~1024 rows × mean tick 15.5 ≈ [0, 16k].
    let span = 16_000.0;
    RowPredicate::TimestampRange {
        min: 0,
        max: (span * frac) as u64,
    }
}

#[test]
fn row_groups_cut_decoded_rows_with_identical_wire_flattened() {
    let world = build(Encoding::Flattened);
    let pred = narrow_window(0.05);
    let (base_wire, base_m) = run(&world, pred.clone(), false, false);
    let (stripe_wire, stripe_m) = run(&world, pred.clone(), true, false);
    let (group_wire, group_m) = run(&world, pred, true, true);
    // Byte-identical client output across all three paths.
    assert_eq!(base_wire, stripe_wire, "stripe pushdown must be lossless");
    assert_eq!(stripe_wire, group_wire, "row-group pruning must be lossless");
    assert!(!group_wire.is_empty(), "window should keep some rows");
    // The zone maps bite below stripe granularity: strictly fewer rows
    // decoded than stripe-only pruning, and fewer bytes fetched (the
    // pruned groups' streams never left storage).
    assert!(
        group_m.decoded_rows.get() * 2 <= stripe_m.decoded_rows.get(),
        "group {} !<< stripe-only {} decoded rows",
        group_m.decoded_rows.get(),
        stripe_m.decoded_rows.get()
    );
    assert!(
        group_m.storage_rx_bytes.get() < stripe_m.storage_rx_bytes.get(),
        "group-pruned plan must fetch fewer bytes"
    );
    assert!(group_m.pruned_groups.get() > 0);
    assert!(group_m.pruned_group_rows.get() > 0);
    assert!(group_m.pruned_group_bytes.get() > 0);
    assert_eq!(stripe_m.pruned_groups.get(), 0, "ablation leaves groups off");
    assert!(base_m.decoded_rows.get() >= world.total_rows / 2);
}

#[test]
fn row_groups_cut_decoded_rows_with_identical_wire_dedup() {
    let world = build(Encoding::Dedup);
    let pred = narrow_window(0.08);
    let (stripe_wire, stripe_m) = run(&world, pred.clone(), true, false);
    let (group_wire, group_m) = run(&world, pred, true, true);
    assert_eq!(
        stripe_wire, group_wire,
        "dedup row-group pruning must be byte-identical"
    );
    assert!(!group_wire.is_empty());
    assert!(group_wire.iter().any(|(_, _, dedup, _)| *dedup));
    // Dedup streams stay whole-stripe (no byte shrink), but the pruned
    // groups' rows never expand: decoded rows drop.
    assert!(
        group_m.decoded_rows.get() < stripe_m.decoded_rows.get(),
        "group {} !< stripe {} decoded rows",
        group_m.decoded_rows.get(),
        stripe_m.decoded_rows.get()
    );
    assert!(group_m.pruned_group_rows.get() > 0);
    // Transforms ran on (at most) the surviving uniques.
    assert!(group_m.transform_rows.get() <= group_m.decoded_rows.get());
}

#[test]
fn broker_path_honors_group_mask_with_identical_wire() {
    let world = build(Encoding::Flattened);
    let pred = narrow_window(0.05);
    // Private group-pruned baseline.
    let (private_wire, _) = run(&world, pred.clone(), true, true);
    // Broker-attached session, same spec: the broker decodes whole
    // stripes (it serves many predicates), the session's mask applies
    // downstream — wire must not change.
    let mut spec = world.spec.clone().with_predicate(pred);
    spec.pipeline.pushdown = true;
    spec.pipeline.row_group_pruning = true;
    let broker = ReadBroker::with_budget_bytes(world.cluster.clone(), 64 << 20);
    let master =
        Master::new_shared(&world.catalog, &world.cluster, spec.clone(), &broker)
            .unwrap();
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(
        Arc::new(spec),
        world.cluster.clone(),
        metrics.clone(),
    );
    core = core.with_broker(master.broker_handle().unwrap());
    let mut wire = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for b in core.process_split(&split).unwrap() {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        master.complete_split(w, split.id);
    }
    assert_eq!(wire, private_wire, "broker path must be byte-identical");
    assert!(metrics.pruned_group_rows.get() > 0);
}

#[test]
fn v2_files_round_trip_through_the_current_reader() {
    // Byte-real old files: footer v2, no zone maps. The current reader
    // must parse them, plan at stripe granularity (stats-less
    // fallback), and decode losslessly — with or without a predicate.
    let samples: Vec<dsi::data::Sample> = (0..96u64)
        .map(|i| {
            let mut s = dsi::data::Sample {
                dense: vec![(FeatureId(0), i as f32)],
                sparse: vec![(
                    FeatureId(100),
                    dsi::data::SparseValue::ids(vec![i, i + 1]),
                )],
                label: (i % 3 == 0) as u64 as f32,
                timestamp: 1000 + i,
            };
            s.sort_features();
            s
        })
        .collect();
    let build = |version: u32| -> Vec<u8> {
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0)],
            vec![FeatureId(100)],
            WriterOptions {
                encoding: Encoding::Flattened,
                stripe_rows: 32,
                rows_per_group: 8,
                footer_version: version,
                ..Default::default()
            },
        );
        w.write_all(samples.clone());
        w.finish()
    };
    let v2 = build(2);
    let v3 = build(3);
    let r2 = DwrfReader::open_table(&v2, "t").unwrap();
    let r3 = DwrfReader::open_table(&v3, "t").unwrap();
    assert!(r2.meta.stripes.iter().all(|s| s.groups.is_empty()));
    assert!(r3.meta.stripes.iter().all(|s| s.groups.len() == 4));
    let proj = Projection::new([FeatureId(0), FeatureId(100)]);
    let pred = RowPredicate::TimestampRange {
        min: 1000,
        max: 1009,
    };
    let decode = |r: &DwrfReader, bytes: &[u8]| -> Vec<dsi::data::Sample> {
        let plan = r.plan_filtered(&proj, None, Some(&pred));
        let bufs = r.fetch_local(bytes, &plan);
        let mut out = Vec::new();
        for sp in &plan.stripes {
            out.extend(
                r.decode_stripe_rows_masked(
                    sp.stripe,
                    &bufs,
                    &proj,
                    DecodeMode::default(),
                    sp.group_mask.as_deref(),
                )
                .unwrap()
                .into_iter()
                .filter(|s| pred.matches_sample(s)),
            );
        }
        out
    };
    let from_v2 = decode(&r2, &v2);
    let from_v3 = decode(&r3, &v3);
    assert_eq!(from_v2, from_v3, "v2 and v3 reads agree row-for-row");
    assert_eq!(from_v2.len(), 10);
    // The v2 plan has no masks (stats-less fallback); the v3 plan does.
    let p2 = r2.plan_filtered(&proj, None, Some(&pred));
    let p3 = r3.plan_filtered(&proj, None, Some(&pred));
    assert!(p2.stripes.iter().all(|s| s.group_mask.is_none()));
    assert_eq!(p2.pruned_groups, 0);
    assert!(p3.pruned_groups > 0);
    assert!(
        p3.pruned_group_bytes > 0,
        "pruned groups' scoped streams leave the v3 I/O plan"
    );
    // Full-scan roundtrip of the v2 file is untouched by all of this.
    let full = r2.plan(&proj, None);
    let bufs = r2.fetch_local(&v2, &full);
    let mut back = Vec::new();
    for si in 0..r2.meta.stripes.len() {
        back.extend(
            r2.decode_stripe_rows(si, &bufs, &proj, DecodeMode::default())
                .unwrap(),
        );
    }
    assert_eq!(back, samples);
}

#[test]
fn fuzzed_footers_error_without_panicking() {
    // Random byte corruption anywhere in the footer region must produce
    // Ok or Err — never a panic, never an out-of-bounds slice when the
    // file is subsequently read.
    let mut w = DwrfWriter::new(
        "t",
        vec![FeatureId(0), FeatureId(1)],
        vec![FeatureId(100)],
        WriterOptions {
            encoding: Encoding::Flattened,
            stripe_rows: 16,
            rows_per_group: 4,
            ..Default::default()
        },
    );
    w.write_all((0..64u64).map(|i| {
        let mut s = dsi::data::Sample {
            dense: vec![(FeatureId(0), i as f32), (FeatureId(1), -(i as f32))],
            sparse: vec![(
                FeatureId(100),
                dsi::data::SparseValue::ids(vec![i]),
            )],
            label: 0.0,
            timestamp: i,
        };
        s.sort_features();
        s
    }));
    let bytes = w.finish();
    let n = bytes.len();
    let flen =
        u64::from_le_bytes(bytes[n - 12..n - 4].try_into().unwrap()) as usize;
    let footer_start = n - 12 - flen;
    let proj = Projection::new([FeatureId(0), FeatureId(1), FeatureId(100)]);
    let mut rng = Pcg32::new(SEED);
    for _ in 0..300 {
        let mut corrupt = bytes.clone();
        // 1–4 byte flips inside the footer (not the trailer, which has
        // its own dedicated guards and tests).
        for _ in 0..(1 + rng.below(4)) {
            let at = footer_start + rng.below(flen as u64) as usize;
            corrupt[at] ^= (1 + rng.below(255)) as u8;
        }
        let Ok(r) = DwrfReader::open_table(&corrupt, "t") else {
            continue; // rejected at parse — the common, correct case
        };
        // If the corrupt footer happened to parse, every planned extent
        // was validated against the file length, so fetching and
        // decoding may fail (crc, lengths) but must not panic.
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&corrupt, &plan);
        for sp in &plan.stripes {
            let _ = r.decode_stripe_rows(
                sp.stripe,
                &bufs,
                &proj,
                DecodeMode::default(),
            );
        }
    }
}

#[test]
fn oversized_footer_reads_through_fetch_meta_reread_loop() {
    // Many stripes × row groups inflate the v3 footer past the 256 KiB
    // bootstrap probe of `DwrfReader::footer_ios` — the caller contract
    // ("re-read if the footer is larger") is now load-bearing. Build
    // such a file and prove the doubling loop in `Master::fetch_meta`
    // (which the broker's footer cache also uses) parses it.
    let cluster = Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    });
    let mut w = DwrfWriter::new(
        "t",
        vec![FeatureId(0), FeatureId(1)],
        vec![FeatureId(100), FeatureId(101)],
        WriterOptions {
            encoding: Encoding::Flattened,
            stripe_rows: 4,
            rows_per_group: 1,
            encrypt: false,
            ..Default::default()
        },
    );
    let rows = 2600u64;
    w.write_all((0..rows).map(|i| {
        let mut s = dsi::data::Sample {
            dense: vec![(FeatureId(0), i as f32), (FeatureId(1), 1.0)],
            sparse: vec![
                (FeatureId(100), dsi::data::SparseValue::ids(vec![i])),
                (FeatureId(101), dsi::data::SparseValue::ids(vec![i + 1])),
            ],
            label: 0.0,
            timestamp: i,
        };
        s.sort_features();
        s
    }));
    let bytes = w.finish();
    let n = bytes.len();
    let flen = u64::from_le_bytes(bytes[n - 12..n - 4].try_into().unwrap());
    assert!(
        flen > 256 * 1024,
        "footer must exceed the bootstrap probe (got {flen} bytes)"
    );
    let file: FileId = cluster.create("warehouse/oversized/part-0.dwrf");
    cluster.append(file, &bytes).unwrap();
    cluster.seal(file);
    let meta = Master::fetch_meta(&cluster, file).unwrap();
    assert_eq!(meta.total_rows, rows);
    assert_eq!(meta.stripes.len(), (rows as usize).div_ceil(4));
    assert!(meta.stripes.iter().all(|s| s.groups.len() == s.rows as usize));
    // The in-memory open path agrees.
    let r = DwrfReader::open_table(&bytes, "t").unwrap();
    assert_eq!(r.meta.total_rows, rows);
}
