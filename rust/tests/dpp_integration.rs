//! DPP coordinator integration: fault tolerance, checkpoint/restore,
//! autoscaling dynamics, and client routing under real sessions.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::{
    Master, MasterCheckpoint, Session, SessionConfig, SessionSpec,
};
use dsi::dwrf::{Projection, WriterOptions};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Duration;

fn fixture(seed: u64) -> (Arc<Cluster>, Catalog, SessionSpec, u64) {
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale {
        rows_per_partition: 192,
        materialized_features: 48,
        partitions: 4,
    };
    let mut rng = Pcg32::new(seed);
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 48,
            ..Default::default()
        },
        seed,
    )
    .unwrap();
    let projection = h.schema.sample_projection(&mut rng, 10, 1.0);
    let dag = session_dag(&mut rng, &rm, &h.schema, &projection);
    let mut spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 24);
    spec.projection = Projection::new(projection);
    let rows = catalog.get(&h.table_name).unwrap().total_rows();
    (cluster, catalog, spec, rows)
}

#[test]
fn worker_crash_mid_session_recovers_all_rows() {
    let (cluster, catalog, spec, rows) = fixture(101);
    let report = Session::run(
        &catalog,
        &cluster,
        spec,
        &SessionConfig {
            initial_workers: 3,
            max_workers: 4,
            clients: 2,
            kill_worker_after_batches: Some(2),
            ..Default::default()
        },
    )
    .unwrap();
    // The crashed worker's split is re-run; duplicates possible but no
    // loss.
    assert!(report.rows_delivered >= rows, "{} < {rows}", report.rows_delivered);
}

#[test]
fn master_checkpoint_restore_resumes_exactly() {
    let (cluster, catalog, spec, _) = fixture(102);
    let master = Master::new(&catalog, &cluster, spec.clone()).unwrap();
    let w = master.register_worker();
    let (_, total) = master.progress();
    // Complete half the splits, checkpoint, "fail over".
    for _ in 0..total / 2 {
        let s = master.fetch_split(w).unwrap();
        master.complete_split(w, s.id);
    }
    let ckpt: MasterCheckpoint = master.checkpoint();
    assert_eq!(ckpt.completed.len(), total / 2);

    let restored = Master::restore(&catalog, &cluster, spec, &ckpt).unwrap();
    let w2 = restored.register_worker();
    let mut remaining = 0;
    while let Some(s) = restored.fetch_split(w2) {
        restored.complete_split(w2, s.id);
        remaining += 1;
    }
    assert_eq!(remaining, total - total / 2);
    assert!(restored.is_done());
}

#[test]
fn autoscaled_session_stays_within_bounds() {
    let (cluster, catalog, spec, rows) = fixture(103);
    let report = Session::run(
        &catalog,
        &cluster,
        spec,
        &SessionConfig {
            initial_workers: 1,
            max_workers: 6,
            clients: 2,
            buffer_per_worker: 2,
            autoscale_every: Some(Duration::from_millis(2)),
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.peak_workers >= 1 && report.peak_workers <= 6);
    assert_eq!(report.rows_delivered, rows);
}

#[test]
fn multiple_clients_split_the_stream_completely() {
    let (cluster, catalog, spec, rows) = fixture(104);
    for clients in [1usize, 2, 3] {
        let report = Session::run(
            &catalog,
            &cluster,
            spec.clone(),
            &SessionConfig {
                initial_workers: 3,
                max_workers: 3,
                clients,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.rows_delivered, rows, "clients={clients}");
    }
}

#[test]
fn paced_trainer_demand_controls_session_rate() {
    let (cluster, catalog, spec, rows) = fixture(105);
    let report = Session::run(
        &catalog,
        &cluster,
        spec,
        &SessionConfig {
            client_rows_per_sec: Some(900.0),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(report.rows_delivered, rows);
    // Must take at least rows/rate seconds.
    assert!(
        report.wall_secs >= rows as f64 / 900.0 * 0.8,
        "wall {:.3}s",
        report.wall_secs
    );
}

#[test]
fn stale_heartbeats_requeue_after_reap() {
    let (cluster, catalog, spec, _) = fixture(106);
    let master = Master::new(&catalog, &cluster, spec).unwrap();
    let w = master.register_worker();
    let s1 = master.fetch_split(w).unwrap();
    let _s2 = master.fetch_split(w).unwrap();
    std::thread::sleep(Duration::from_millis(25));
    assert_eq!(master.reap_expired(Duration::from_millis(5)), 2);
    // A fresh worker finishes everything, including the reaped splits.
    let w2 = master.register_worker();
    let mut n = 0;
    while let Some(s) = master.fetch_split(w2) {
        master.complete_split(w2, s.id);
        n += 1;
    }
    assert!(n >= 2);
    assert!(master.is_done());
    let _ = s1;
}

#[test]
fn tensor_cache_serves_second_epoch_without_storage() {
    use dsi::dpp::{TensorCache, WorkerCore};
    use dsi::metrics::EtlMetrics;
    let (cluster, catalog, spec, _) = fixture(107);
    let cache = TensorCache::new(64 << 20);
    let spec = Arc::new(spec);

    let run_epoch = |metrics: Arc<EtlMetrics>| {
        let master = Master::new(&catalog, &cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let mut core =
            WorkerCore::new(spec.clone(), cluster.clone(), metrics)
                .with_tensor_cache(cache.clone());
        let mut batches = Vec::new();
        while let Some(split) = master.fetch_split(w) {
            batches.extend(core.process_split(&split).unwrap());
            master.complete_split(w, split.id);
        }
        batches
    };

    let m1 = Arc::new(EtlMetrics::default());
    cluster.reset_stats();
    let first = run_epoch(m1.clone());
    let storage_first = cluster.stats().reads;
    assert!(storage_first > 0);

    let m2 = Arc::new(EtlMetrics::default());
    cluster.reset_stats();
    let second = run_epoch(m2.clone());
    let storage_second = cluster.stats().reads;

    // Second epoch: full cache hits — identical tensors, no data-plane
    // storage I/O (only the Master's 4 control-plane footer fetches),
    // no extract/transform time.
    assert!(
        storage_second <= 4,
        "cached epoch read data: {storage_second} reads"
    );
    assert!(storage_first > storage_second * 5);
    assert_eq!(m1.samples.get(), m2.samples.get());
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(second.iter()) {
        assert_eq!(a.bytes, b.bytes);
    }
    assert!(cache.hit_rate() > 0.49, "rate {}", cache.hit_rate());
    assert_eq!(m2.t_transform.secs(), 0.0);
}
