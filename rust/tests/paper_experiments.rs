//! Smoke + shape tests over the paper experiment drivers at tiny scale:
//! every driver must run, and the qualitative claims the paper makes must
//! hold in the reproduction.

use dsi::config::SimScale;
use dsi::paper;
use dsi::util::json::Json;

fn tiny() -> SimScale {
    SimScale {
        rows_per_partition: 128,
        materialized_features: 64,
        partitions: 2,
    }
}

#[test]
fn every_experiment_runs_at_tiny_scale() {
    for exp in paper::ALL_EXPERIMENTS {
        // table12/power at tiny scale use the smoke-test path.
        let scale = if *exp == "table12" || *exp == "power" {
            SimScale {
                rows_per_partition: 128,
                materialized_features: 64,
                partitions: 2,
            }
        } else {
            tiny()
        };
        let out = paper::run(exp, &scale, 7);
        assert!(out.is_ok(), "{exp} failed: {:?}", out.err());
    }
}

#[test]
fn fig1_dsi_power_is_substantial() {
    let j = paper::run("fig1", &tiny(), 11).unwrap();
    for rm in ["RM1", "RM2", "RM3"] {
        let o = j.get(rm).unwrap();
        let storage = o.get("storage").unwrap().as_f64().unwrap();
        let preproc = o.get("preproc").unwrap().as_f64().unwrap();
        assert!(
            storage + preproc > 0.3,
            "{rm}: DSI fraction {}",
            storage + preproc
        );
    }
}

#[test]
fn fig2_growth_factors() {
    let j = paper::run("fig2", &tiny(), 1).unwrap();
    assert!((j.get("size_growth").unwrap().as_f64().unwrap() - 2.0).abs() < 0.1);
    assert!((j.get("bw_growth").unwrap().as_f64().unwrap() - 4.0).abs() < 0.2);
}

#[test]
fn fig5_shows_peaks() {
    let j = paper::run("fig5", &tiny(), 5).unwrap();
    assert!(j.get("peak_over_mean").unwrap().as_f64().unwrap() > 1.3);
}

#[test]
fn fig6_binpacking_saves_copies() {
    let j = paper::run("fig6", &tiny(), 5).unwrap();
    let balanced = j.get("balanced_copies").unwrap().as_f64().unwrap();
    let packed = j.get("packed_copies").unwrap().as_f64().unwrap();
    assert!(packed < balanced);
}

#[test]
fn table8_demand_ordering_matches_paper() {
    let j = paper::run("table8", &tiny(), 13).unwrap();
    if let Some(Json::Arr(gbps)) = j.get("gbps") {
        let v: Vec<f64> = gbps.iter().map(|x| x.as_f64().unwrap()).collect();
        assert!(v[0] > v[2] && v[2] > v[1], "RM1 > RM3 > RM2: {v:?}");
    } else {
        panic!("missing gbps");
    }
}

#[test]
fn table12_smoke_shape() {
    let j = paper::run("table12", &tiny(), 42).unwrap();
    let dpp: Vec<f64> = match j.get("dpp") {
        Some(Json::Arr(a)) => a.iter().map(|x| x.as_f64().unwrap()).collect(),
        _ => panic!("missing dpp"),
    };
    let storage: Vec<f64> = match j.get("storage") {
        Some(Json::Arr(a)) => a.iter().map(|x| x.as_f64().unwrap()).collect(),
        _ => panic!("missing storage"),
    };
    // Minimal invariants that must hold even at smoke scale:
    assert!((dpp[0] - 1.0).abs() < 1e-9);
    assert!(dpp[1] > 1.0, "FF must speed up the worker: {dpp:?}");
    assert!(
        storage[1] < 0.6,
        "FF must hurt storage throughput: {storage:?}"
    );
    assert!(
        storage[4] > storage[1] * 2.0,
        "CR must recover storage: {storage:?}"
    );
}

#[test]
fn fig10_overread_story() {
    let j = paper::run("fig10", &tiny(), 3).unwrap();
    let read = |k: &str| {
        j.get(k).unwrap().get("read").unwrap().as_f64().unwrap()
    };
    assert!(read("FF") <= read("map (baseline)"));
    assert!(read("FF+CR+FR") <= read("FF+CR"));
}
