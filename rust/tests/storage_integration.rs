//! Storage-stack integration: DWRF files through the Tectonic cluster,
//! optimization mechanisms end to end, and device-model invariants.

use dsi::config::{DeviceSpec, RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::Master;
use dsi::dwrf::plan::COALESCE_WINDOW;
use dsi::dwrf::{DecodeMode, DwrfReader, Encoding, Projection, WriterOptions};
use dsi::schema::FeatureId;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::warehouse::Catalog;

fn build(encoding: Encoding, seed: u64) -> (Cluster, Catalog, String, Vec<FeatureId>) {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 256,
        materialized_features: 96,
        partitions: 2,
    };
    let cluster = Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    });
    let catalog = Catalog::new();
    let h = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 64,
            ..Default::default()
        },
        seed,
    )
    .unwrap();
    let proj: Vec<FeatureId> =
        h.schema.features.iter().take(12).map(|f| f.id).collect();
    (cluster, catalog, h.table_name, proj)
}

#[test]
fn remote_footer_fetch_matches_local_parse() {
    let (cluster, catalog, table, _) = build(Encoding::Flattened, 7);
    let t = catalog.get(&table).unwrap();
    for p in &t.partitions {
        // Remote path: ranged tail reads through the device model.
        let meta = Master::fetch_meta(&cluster, p.file).unwrap();
        // Local path: read the whole file and parse.
        let bytes = cluster
            .read_range(
                p.file,
                dsi::dwrf::IoRange {
                    offset: 0,
                    len: p.bytes,
                },
            )
            .unwrap();
        let local = DwrfReader::open_table(&bytes, &table).unwrap();
        assert_eq!(meta.total_rows, local.meta.total_rows);
        assert_eq!(meta.stripes.len(), local.meta.stripes.len());
    }
}

#[test]
fn planned_reads_decode_through_cluster() {
    let (cluster, catalog, table, proj) = build(Encoding::Flattened, 8);
    let t = catalog.get(&table).unwrap();
    let projection = Projection::new(proj);
    let mut rows = 0u64;
    for p in &t.partitions {
        let meta = Master::fetch_meta(&cluster, p.file).unwrap();
        let reader = DwrfReader::from_meta(meta, &table);
        let plan = reader.plan(&projection, Some(COALESCE_WINDOW));
        for sp in &plan.stripes {
            let bufs = cluster.execute_ios(p.file, &sp.ios).unwrap();
            let batch = reader
                .decode_stripe_columnar(
                    sp.stripe,
                    &bufs,
                    &projection,
                    DecodeMode::default(),
                )
                .unwrap();
            rows += batch.num_rows as u64;
        }
    }
    assert_eq!(rows, t.total_rows());
}

#[test]
fn coalescing_reduces_iops_at_equal_useful_bytes() {
    let (cluster, catalog, table, proj) = build(Encoding::Flattened, 9);
    let t = catalog.get(&table).unwrap();
    let projection = Projection::new(proj);
    let p = &t.partitions[0];
    let meta = Master::fetch_meta(&cluster, p.file).unwrap();
    let reader = DwrfReader::from_meta(meta, &table);
    let plain = reader.plan(&projection, None);
    let coalesced = reader.plan(&projection, Some(COALESCE_WINDOW));
    assert_eq!(plain.useful_bytes, coalesced.useful_bytes);
    assert!(coalesced.num_ios() < plain.num_ios());
    assert!(coalesced.read_bytes >= plain.read_bytes);

    // Device time: execute both against the cluster and compare.
    cluster.reset_stats();
    for sp in &plain.stripes {
        cluster.execute_ios(p.file, &sp.ios).unwrap();
    }
    let t_plain = cluster.stats().device_secs;
    cluster.reset_stats();
    for sp in &coalesced.stripes {
        cluster.execute_ios(p.file, &sp.ios).unwrap();
    }
    let t_coalesced = cluster.stats().device_secs;
    assert!(
        t_coalesced < t_plain,
        "coalescing must cut device time: {t_coalesced} vs {t_plain}"
    );
}

#[test]
fn map_encoding_reads_more_than_flattened_under_projection() {
    let (c1, cat1, t1, proj) = build(Encoding::Map, 10);
    let (c2, cat2, t2, _) = build(Encoding::Flattened, 10);
    let projection = Projection::new(proj);
    let read_bytes = |cluster: &Cluster, catalog: &Catalog, table: &str| -> u64 {
        let t = catalog.get(table).unwrap();
        let mut total = 0;
        for p in &t.partitions {
            let meta = Master::fetch_meta(cluster, p.file).unwrap();
            let reader = DwrfReader::from_meta(meta, table);
            total += reader.plan(&projection, None).read_bytes;
        }
        total
    };
    let map_bytes = read_bytes(&c1, &cat1, &t1);
    let flat_bytes = read_bytes(&c2, &cat2, &t2);
    assert!(
        flat_bytes * 2 < map_bytes,
        "flattened {flat_bytes} should be well under map {map_bytes}"
    );
}

#[test]
fn ssd_cluster_shrugs_off_small_reads() {
    // The §7.2 heterogeneous-media argument, end to end.
    let mk = |device: DeviceSpec| {
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let cluster = Cluster::new(ClusterConfig {
            device,
            chunk_bytes: 128 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            11,
        )
        .unwrap();
        (cluster, catalog, h.table_name)
    };
    let run = |cluster: &Cluster, catalog: &Catalog, table: &str| -> f64 {
        let t = catalog.get(table).unwrap();
        let projection = Projection::new(
            t.schema.features.iter().take(6).map(|f| f.id),
        );
        cluster.reset_stats();
        for p in &t.partitions {
            let meta = Master::fetch_meta(cluster, p.file).unwrap();
            let reader = DwrfReader::from_meta(meta, table);
            let plan = reader.plan(&projection, None);
            for sp in &plan.stripes {
                cluster.execute_ios(p.file, &sp.ios).unwrap();
            }
        }
        cluster.stats().device_secs
    };
    let (hc, hcat, ht) = mk(DeviceSpec::hdd());
    let (sc, scat, st) = mk(DeviceSpec::ssd());
    let hdd_secs = run(&hc, &hcat, &ht);
    let ssd_secs = run(&sc, &scat, &st);
    assert!(
        hdd_secs / ssd_secs > 50.0,
        "small-read workload: HDD {hdd_secs:.4}s vs SSD {ssd_secs:.6}s"
    );
}
