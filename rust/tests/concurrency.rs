//! Control-plane concurrency regressions: the `MemoryBudget` against a
//! sequential oracle, Master lease races (failure / drain vs a late
//! completion), and a broker that keeps serving other sessions after a
//! worker thread dies mid-decode. The same protocols are model-checked
//! exhaustively under `--cfg loom` (`dsi::sync::models`); these tests
//! keep the real `std::sync` build honest.

use dsi::broker::{MemoryBudget, ReadBroker};
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::Master;
use dsi::dwrf::{Projection, WriterOptions};
use dsi::schema::FeatureId;
use dsi::tectonic::{Cluster, ClusterConfig, FileId};
use dsi::util::prop::check;
use dsi::warehouse::Catalog;
use std::collections::HashMap;
use std::sync::Arc;

/// Every reserve/release decision the pool makes must match a plain
/// checked-arithmetic model replayed over the same script.
#[test]
fn budget_matches_sequential_oracle() {
    check("memory budget vs sequential oracle", 200, |g| {
        let total = g.u64(1..2000);
        let budget = MemoryBudget::new(total);
        let mut oracle: u64 = 0;
        let mut held: Vec<u64> = Vec::new();
        let ops = g.len(64);
        for step in 0..ops {
            if held.is_empty() || g.bool() {
                let amt = g.u64(0..total + 50);
                let want = oracle
                    .checked_add(amt)
                    .is_some_and(|next| next <= total);
                let got = budget.try_reserve(amt);
                if got != want {
                    return Err(format!(
                        "step {step}: reserve({amt}) -> {got}, oracle \
                         expected {want} (used {oracle}/{total})"
                    ));
                }
                if got {
                    oracle += amt;
                    held.push(amt);
                }
            } else {
                let amt = held.swap_remove(g.usize(0..held.len()));
                budget.release(amt);
                oracle -= amt;
            }
            if budget.used() != oracle {
                return Err(format!(
                    "step {step}: used {} != oracle {oracle}",
                    budget.used()
                ));
            }
        }
        Ok(())
    });
}

/// Threads hammer one pool, each releasing only what it reserved: the
/// pool never exceeds its total mid-flight and drains back to zero.
#[test]
fn budget_concurrent_reserve_release_balances() {
    let total = 10_000u64;
    let budget = MemoryBudget::new(total);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let b = budget.clone();
        handles.push(std::thread::spawn(move || {
            let mut held: Vec<u64> = Vec::new();
            let (mut reserved, mut released) = (0u64, 0u64);
            for i in 0..2000u64 {
                let amt = (t * 2711 + i * 37) % 400 + 1;
                if i % 3 != 2 {
                    if b.try_reserve(amt) {
                        held.push(amt);
                        reserved += amt;
                    }
                } else if let Some(amt) = held.pop() {
                    b.release(amt);
                    released += amt;
                }
                let used = b.used();
                assert!(used <= total, "used {used} > total {total}");
            }
            for amt in held {
                b.release(amt);
                released += amt;
            }
            (reserved, released)
        }));
    }
    let (mut reserved, mut released) = (0u64, 0u64);
    for h in handles {
        let (r, l) = h.join().unwrap();
        reserved += r;
        released += l;
    }
    assert_eq!(reserved, released, "threads release all they reserve");
    assert_eq!(budget.used(), 0, "pool drains to zero");
}

/// Race a completion against the failure detector declaring its worker
/// dead: whichever order the two locks interleave in, the settled split
/// must never be served again.
#[test]
fn completed_split_never_requeued_by_worker_failure() {
    for round in 0..100 {
        let m = Arc::new(Master::synthetic(1));
        let w1 = m.register_worker();
        let id = m.fetch_split(w1).expect("one split queued").id;
        let ma = Arc::clone(&m);
        let mb = Arc::clone(&m);
        let a = std::thread::spawn(move || ma.complete_split(w1, id));
        let b = std::thread::spawn(move || mb.worker_failed(w1));
        a.join().unwrap();
        b.join().unwrap();
        let w2 = m.register_worker();
        assert!(
            m.fetch_split(w2).is_none(),
            "round {round}: completed split was requeued"
        );
        assert!(m.is_done(), "round {round}: leftover queue/lease");
        assert_eq!(m.progress(), (1, 1), "round {round}");
    }
}

/// Same race against a graceful retire + drain: draining requeues the
/// retiree's leases, but a split that already completed stays settled.
#[test]
fn retired_worker_drain_never_requeues_completed_split() {
    for round in 0..100 {
        let m = Arc::new(Master::synthetic(1));
        let w1 = m.register_worker();
        let id = m.fetch_split(w1).expect("one split queued").id;
        let ma = Arc::clone(&m);
        let mb = Arc::clone(&m);
        let a = std::thread::spawn(move || ma.complete_split(w1, id));
        let b = std::thread::spawn(move || {
            mb.retire_worker(w1);
            mb.worker_drained(w1);
        });
        a.join().unwrap();
        b.join().unwrap();
        let w2 = m.register_worker();
        assert!(
            m.fetch_split(w2).is_none(),
            "round {round}: completed split was requeued"
        );
        assert!(m.is_done(), "round {round}: leftover queue/lease");
        assert_eq!(m.progress(), (1, 1), "round {round}");
    }
}

fn tiny_world() -> (Arc<Cluster>, String, Vec<FileId>, Vec<FeatureId>) {
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 64 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale::tiny();
    let h = build_dataset(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 16,
            ..Default::default()
        },
        7,
    )
    .unwrap();
    let files: Vec<FileId> = catalog
        .get(&h.table_name)
        .unwrap()
        .partitions
        .iter()
        .map(|p| p.file)
        .collect();
    let feats: Vec<FeatureId> =
        h.schema.features.iter().map(|f| f.id).collect();
    (cluster, h.table_name, files, feats)
}

/// A worker thread dying mid-decode (panicking while it holds a served
/// stripe handle) must not wedge the broker: the other session still
/// drains every stripe, and unregistering the dead session frees every
/// byte it pinned.
#[test]
fn broker_keeps_serving_after_worker_panic() {
    let (cluster, table, files, feats) = tiny_world();
    let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
    let proj = Projection::new(feats.iter().copied());
    let file = files[0];
    let stripes =
        Master::fetch_meta(&cluster, file).unwrap().stripes.len();
    assert!(stripes >= 2, "need multiple stripes to share");
    let all: Vec<usize> = (0..stripes).collect();
    let interest = |stripes: &[usize]| -> HashMap<FileId, Vec<usize>> {
        let mut m = HashMap::new();
        m.insert(file, stripes.to_vec());
        m
    };
    let s_dead = broker.register(&table, &proj, interest(&all));
    let s_live = broker.register(&table, &proj, interest(&all));

    let dead = {
        let broker = Arc::clone(&broker);
        std::thread::spawn(move || {
            let served = broker.get_stripe(s_dead, file, 0).unwrap();
            assert!(!served.from_buffer, "first serve pays the fetch");
            panic!("worker died mid-decode");
        })
    };
    assert!(dead.join().is_err(), "worker thread should have panicked");

    // The surviving session is unaffected: every stripe still serves,
    // and stripe 0 rides the buffer the dead worker already filled.
    let first = broker.get_stripe(s_live, file, 0).unwrap();
    assert!(first.from_buffer, "dead worker's fetch is still shared");
    for &s in &all[1..] {
        broker.get_stripe(s_live, file, s).unwrap();
    }
    // The dead session's unconsumed interest still pins stripes 1..n;
    // unregistering it releases them.
    broker.unregister(s_dead);
    broker.unregister(s_live);
    assert_eq!(broker.buffered_stripes(), 0, "nothing stays resident");
    assert_eq!(broker.budget().used(), 0, "every byte released");
}
