//! Cross-job shared reads must be lossless: concurrent sessions with
//! *different* predicates (and projections) over the same files must
//! each receive exactly the wire bytes the single-session private-scan
//! path produces — for Flattened and Dedup encodings — while the broker
//! actually shares fetched stripes between them.

use dsi::broker::ReadBroker;
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::{Encoding, WriterOptions};
use dsi::filter::RowPredicate;
use dsi::metrics::EtlMetrics;
use dsi::schema::FeatureKind;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::warehouse::Catalog;
use std::sync::Arc;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    /// Sessions over two features / one feature (nested projections).
    spec_wide: SessionSpec,
    spec_narrow: SessionSpec,
    /// A timestamp cut that splits the stripes (some pruned, some kept).
    ts_cut: u64,
}

fn build(encoding: Encoding, dup_factor: usize) -> World {
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 64 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale::tiny();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 16,
            ..Default::default()
        },
        31,
        &GenOptions {
            dup_factor,
            tick_max: 40, // spread timestamps so recency windows bite
            ..Default::default()
        },
    )
    .unwrap();

    let dense = h
        .schema
        .features
        .iter()
        .find(|f| matches!(f.kind, FeatureKind::Dense))
        .unwrap()
        .id;
    let sparse = h
        .schema
        .features
        .iter()
        .find(|f| !matches!(f.kind, FeatureKind::Dense))
        .unwrap()
        .id;
    let mut wide_dag = TransformDag::default();
    let d = wide_dag.input_dense(dense);
    let c = wide_dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![d]);
    wide_dag.output(dense, c);
    let s = wide_dag.input_sparse(sparse);
    let hh = wide_dag.apply(
        Op::SigridHash {
            salt: 5,
            modulus: 1 << 12,
        },
        vec![s],
    );
    wide_dag.output(sparse, hh);
    let spec_wide = SessionSpec::from_dag(&h.table_name, 0, 10, wide_dag, 8);

    let mut narrow_dag = TransformDag::default();
    let d2 = narrow_dag.input_dense(dense);
    let c2 = narrow_dag.apply(Op::Clamp { lo: -1.0, hi: 1.0 }, vec![d2]);
    narrow_dag.output(dense, c2);
    let spec_narrow =
        SessionSpec::from_dag(&h.table_name, 0, 10, narrow_dag, 8);

    // A cut splitting stripes: the median stripe max-timestamp.
    let mut maxes: Vec<u64> = Vec::new();
    for p in &catalog.get(&h.table_name).unwrap().partitions {
        let meta = Master::fetch_meta(&cluster, p.file).unwrap();
        for st in &meta.stripes {
            maxes.push(st.stats.max_timestamp);
        }
    }
    maxes.sort_unstable();
    let ts_cut = maxes[maxes.len() / 2];

    World {
        cluster,
        catalog,
        spec_wide,
        spec_narrow,
        ts_cut,
    }
}

type Wire = Vec<(u64, usize, bool, Vec<u8>)>;

fn drain(
    world: &World,
    spec: SessionSpec,
    broker: Option<&Arc<ReadBroker>>,
) -> (Master, WorkerCore) {
    let mut spec = spec;
    spec.pipeline.shared_reads = broker.is_some();
    let master = match broker {
        Some(b) => Master::new_shared(
            &world.catalog,
            &world.cluster,
            spec.clone(),
            b,
        ),
        None => Master::new(&world.catalog, &world.cluster, spec.clone()),
    }
    .unwrap();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(Arc::new(spec), world.cluster.clone(), metrics);
    if let Some(h) = master.broker_handle() {
        core = core.with_broker(h);
    }
    (master, core)
}

fn run_to_end(master: Master, mut core: WorkerCore) -> Wire {
    let w = master.register_worker();
    let mut wire = Wire::new();
    while let Some(split) = master.fetch_split(w) {
        for b in core.process_split(&split).unwrap() {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        master.complete_split(w, split.id);
    }
    wire
}

fn lossless_two_sessions(encoding: Encoding, dup_factor: usize) {
    let world = build(encoding, dup_factor);
    // Session 1: recency window over the wide projection (prunes some
    // stripes). Session 2: deterministic sample over the narrow
    // projection (touches every stripe).
    let spec1 = world.spec_wide.clone().with_predicate(
        RowPredicate::TimestampRange {
            min: 0,
            max: world.ts_cut,
        },
    );
    let spec2 = world
        .spec_narrow
        .clone()
        .with_predicate(RowPredicate::SampleRate { rate: 0.5, seed: 9 });

    // Private baselines.
    let (m1, c1) = drain(&world, spec1.clone(), None);
    let base1 = run_to_end(m1, c1);
    let (m2, c2) = drain(&world, spec2.clone(), None);
    let base2 = run_to_end(m2, c2);
    assert!(!base1.is_empty() && !base2.is_empty());

    // Brokered, concurrent: both sessions registered before either
    // runs, then drained on separate threads.
    let broker = ReadBroker::with_budget_bytes(world.cluster.clone(), 64 << 20);
    let (sm1, sc1) = drain(&world, spec1, Some(&broker));
    let (sm2, sc2) = drain(&world, spec2, Some(&broker));
    let t1 = std::thread::spawn(move || run_to_end(sm1, sc1));
    let t2 = std::thread::spawn(move || run_to_end(sm2, sc2));
    let got1 = t1.join().unwrap();
    let got2 = t2.join().unwrap();

    assert_eq!(got1, base1, "session 1 wire must be byte-identical");
    assert_eq!(got2, base2, "session 2 wire must be byte-identical");
    assert!(
        broker.metrics.shared_reads.get() > 0,
        "overlapping stripes must actually be shared"
    );
    // Every serve is either a hit or a miss; misses never exceed the
    // distinct stripe population.
    let serves = broker.metrics.shared_reads.get()
        + broker.metrics.broker_misses.get();
    assert!(serves > broker.metrics.broker_misses.get());
    // Once both sessions finish, no stripe stays pinned.
    assert_eq!(broker.buffered_stripes(), 0);
    assert_eq!(broker.budget().used(), 0);
}

#[test]
fn two_predicated_sessions_lossless_flattened() {
    lossless_two_sessions(Encoding::Flattened, 1);
}

#[test]
fn two_predicated_sessions_lossless_dedup() {
    lossless_two_sessions(Encoding::Dedup, 3);
}

#[test]
fn dedup_wire_actually_uses_dedup_path() {
    let world = build(Encoding::Dedup, 3);
    let broker = ReadBroker::with_budget_bytes(world.cluster.clone(), 64 << 20);
    let (m, c) = drain(&world, world.spec_wide.clone(), Some(&broker));
    let wire = run_to_end(m, c);
    assert!(
        wire.iter().any(|b| b.2),
        "shared path must preserve dedup-aware wire batches"
    );
}

#[test]
fn table_scoped_sessions_share_footers() {
    let world = build(Encoding::Flattened, 1);
    let broker = ReadBroker::with_budget_bytes(world.cluster.clone(), 64 << 20);
    let (m1, c1) = drain(&world, world.spec_wide.clone(), Some(&broker));
    let _w1 = run_to_end(m1, c1);
    // A second session over the same table issues no footer I/O at all
    // at plan time (stripe data was consumed already by session 1, so
    // its own reads are data only).
    world.cluster.reset_stats();
    let (m2, _c2) = drain(&world, world.spec_narrow.clone(), Some(&broker));
    assert_eq!(
        world.cluster.stats().reads,
        0,
        "planning a shared session reuses cached footers"
    );
    drop(m2);
}
