//! Property-based tests over coordinator and format invariants (using the
//! in-repo `util::prop` mini-harness; proptest is unavailable offline).

use dsi::data::{ColumnarBatch, Sample, SparseValue};
use dsi::dedup::DedupIndex;
use dsi::dpp::client::partition_round_robin;
use dsi::dpp::split::splits_for_partition;
use dsi::dpp::{estimate_worker_seconds, DedupTensorBatch, TensorBatch};
use dsi::dwrf::plan::{coalesce, IoRange};
use dsi::obs::Histogram;
use dsi::dwrf::{DecodeMode, DwrfReader, DwrfWriter, Encoding, Projection, WriterOptions};
use dsi::schema::FeatureId;
use dsi::tectonic::FileId;
use dsi::transforms::{Op, Value};
use dsi::util::bytes::{get_varint, put_varint, unzigzag, zigzag};
use dsi::util::prop::{check, Gen};

#[test]
fn prop_estimated_worker_seconds_monotone_as_selectivity_drops() {
    // The autoscaler's planning model: narrowing a predicate (lower
    // selectivity, and stripe pruning that can only grow) never raises
    // the estimated worker-seconds for the session.
    check("worker-seconds monotone in selectivity", 400, |g| {
        let rows = g.u64(1..1_000_000);
        let unit = |g: &mut Gen| g.u64(0..1_000_001) as f64 / 1e6;
        let decode = unit(g) * 1e-3;
        let process = unit(g) * 1e-3;
        let sel_hi = unit(g);
        let sel_lo = sel_hi * unit(g);
        // Pruning can cover at most the filtered-away fraction, and the
        // narrower predicate prunes at least as much as the wider one.
        let prune_hi = (1.0 - sel_hi) * unit(g);
        let prune_lo =
            prune_hi + ((1.0 - sel_lo) - prune_hi).max(0.0) * unit(g);
        let hi = estimate_worker_seconds(rows, sel_hi, prune_hi, decode, process);
        let lo = estimate_worker_seconds(rows, sel_lo, prune_lo, decode, process);
        if lo <= hi + 1e-9 {
            Ok(())
        } else {
            Err(format!(
                "sel {sel_lo:.4} (prune {prune_lo:.4}) cost {lo} > \
                 sel {sel_hi:.4} (prune {prune_hi:.4}) cost {hi}"
            ))
        }
    });
}

#[test]
fn prop_histogram_quantiles_ordered_and_bracket_max() {
    // Quantiles are monotone in q, and q=1.0 reports the max's bucket
    // upper bound: never below the true max, and at most one
    // sub-bucket (12.5%) above it.
    check("histogram quantile order", 200, |g| {
        let h = Histogram::new();
        let n = g.usize(1..200);
        let mut max = 0u64;
        for _ in 0..n {
            // Stay below the clamped top bucket (~2^43 ns).
            let ns = g.u64(0..1 << 42);
            max = max.max(ns);
            h.record_ns(ns);
        }
        let qs = [0.5, 0.95, 0.99, 1.0].map(|q| h.quantile(q));
        for w in qs.windows(2) {
            if w[0] > w[1] {
                return Err(format!("unordered quantiles: {qs:?}"));
            }
        }
        let max_secs = max as f64 / 1e9;
        let p100 = qs[3];
        if p100 < max_secs {
            return Err(format!("p100 {p100} under max {max_secs}"));
        }
        if p100 > max_secs * 1.125 + 1e-9 {
            return Err(format!(
                "p100 {p100} above bucket bound of max {max_secs}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_histogram_merge_equals_concat() {
    // Bucketing is deterministic per value, so folding two histograms
    // together is indistinguishable from recording both streams into
    // one — counts, total time, and every quantile agree exactly.
    check("histogram merge == concat", 200, |g| {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for ns in g.vec_u64(0..1 << 42, 60) {
            a.record_ns(ns);
            all.record_ns(ns);
        }
        for ns in g.vec_u64(0..1 << 42, 60) {
            b.record_ns(ns);
            all.record_ns(ns);
        }
        a.merge(&b);
        if a.count() != all.count() {
            return Err(format!("count {} != {}", a.count(), all.count()));
        }
        if a.total_secs() != all.total_secs() {
            return Err("total time diverged".into());
        }
        for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            if a.quantile(q) != all.quantile(q) {
                return Err(format!(
                    "q={q}: {} != {}",
                    a.quantile(q),
                    all.quantile(q)
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_varint_roundtrip() {
    check("varint roundtrip", 500, |g| {
        let v = g.u64(0..u64::MAX);
        let mut buf = Vec::new();
        put_varint(&mut buf, v);
        let (back, n) = get_varint(&buf).ok_or("decode failed")?;
        if back != v || n != buf.len() {
            return Err(format!("{v} -> {back}"));
        }
        Ok(())
    });
}

#[test]
fn prop_zigzag_roundtrip() {
    check("zigzag roundtrip", 500, |g| {
        let v = g.u64(0..u64::MAX) as i64;
        if unzigzag(zigzag(v)) != v {
            return Err(format!("{v}"));
        }
        Ok(())
    });
}

fn random_samples(g: &mut Gen) -> Vec<Sample> {
    let rows = g.usize(1..40);
    (0..rows)
        .map(|r| {
            let mut s = Sample {
                label: if g.bool() { 1.0 } else { 0.0 },
                timestamp: g.u64(0..1 << 40),
                ..Default::default()
            };
            for fid in 0..g.usize(0..6) as u32 {
                if g.bool() {
                    s.dense.push((FeatureId(fid), g.f32()));
                }
            }
            for fid in 10..(10 + g.usize(0..5)) as u32 {
                if g.bool() {
                    // Empty lists are semantically "absent" (the formats
                    // collapse them, like production); never emit them.
                    let ids = g.vec_u64(0..1 << 30, 12);
                    if !ids.is_empty() {
                        s.sparse
                            .push((FeatureId(fid), SparseValue::ids(ids)));
                    }
                }
            }
            let _ = r;
            s.sort_features();
            s
        })
        .collect()
}

#[test]
fn prop_dwrf_roundtrip_any_samples_both_encodings() {
    check("dwrf roundtrip", 60, |g| {
        let samples = random_samples(g);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let stripe_rows = g.usize(1..16);
        for encoding in [Encoding::Map, Encoding::Flattened] {
            let mut w = DwrfWriter::new(
                "prop",
                dense_ids.clone(),
                sparse_ids.clone(),
                WriterOptions {
                    encoding,
                    stripe_rows,
                    ..Default::default()
                },
            );
            w.write_all(samples.clone());
            let bytes = w.finish();
            let r = DwrfReader::open_table(&bytes, "prop")
                .map_err(|e| e.to_string())?;
            let proj = Projection::new(
                dense_ids.iter().chain(sparse_ids.iter()).copied(),
            );
            let plan = r.plan(&proj, None);
            let bufs = r.fetch_local(&bytes, &plan);
            let mut back = Vec::new();
            for s in 0..r.meta.stripes.len() {
                back.extend(
                    r.decode_stripe_rows(s, &bufs, &proj, DecodeMode::default())
                        .map_err(|e| e.to_string())?,
                );
            }
            if back != samples {
                return Err(format!(
                    "mismatch ({encoding:?}, {} rows, stripe {stripe_rows})",
                    samples.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dedup_dwrf_roundtrips_duplicated_sample_sets() {
    check("dedup dwrf roundtrip", 40, |g| {
        // Fan each base sample out into 1..=4 payload-identical copies
        // with independent labels, give every row a unique timestamp
        // (the canonical order key), then scatter.
        let base = random_samples(g);
        let mut rows = Vec::new();
        for s in &base {
            for _ in 0..g.usize(1..5) {
                let mut c = s.clone();
                c.label = if g.bool() { 1.0 } else { 0.0 };
                rows.push(c);
            }
        }
        for (i, r) in rows.iter_mut().enumerate() {
            r.timestamp = i as u64;
        }
        g.rng.shuffle(&mut rows);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let stripe_rows = g.usize(1..16);
        let mut w = DwrfWriter::new(
            "prop",
            dense_ids.clone(),
            sparse_ids.clone(),
            WriterOptions {
                encoding: Encoding::Dedup,
                stripe_rows,
                dedup_window_stripes: g.usize(1..6),
                ..Default::default()
            },
        );
        w.write_all(rows.clone());
        let bytes = w.finish();
        let r = DwrfReader::open_table(&bytes, "prop")
            .map_err(|e| e.to_string())?;
        if r.meta.total_rows as usize != rows.len() {
            return Err("row count lost".into());
        }
        let proj = Projection::new(
            dense_ids.iter().chain(sparse_ids.iter()).copied(),
        );
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let mut back = Vec::new();
        for s in 0..r.meta.stripes.len() {
            back.extend(
                r.decode_stripe_rows(s, &bufs, &proj, DecodeMode::default())
                    .map_err(|e| e.to_string())?,
            );
        }
        // The clustering window permutes rows; the multiset must be
        // exactly preserved (unique timestamps give a canonical order).
        back.sort_by_key(|s| s.timestamp);
        let mut want = rows.clone();
        want.sort_by_key(|s| s.timestamp);
        if back != want {
            return Err(format!(
                "dedup roundtrip lost data ({} rows, stripe {stripe_rows})",
                rows.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_dedup_index_expansion_is_identity() {
    check("dedup index expansion", 80, |g| {
        let base = random_samples(g);
        let mut rows = Vec::new();
        for s in &base {
            for _ in 0..g.usize(1..4) {
                rows.push(s.clone());
            }
        }
        g.rng.shuffle(&mut rows);
        let idx = DedupIndex::analyze(&rows);
        if idx.inverse.len() != rows.len() {
            return Err("inverse arity".into());
        }
        if idx.unique_count() > rows.len() {
            return Err("more uniques than rows".into());
        }
        for (r, &u) in idx.inverse.iter().enumerate() {
            let rep = &rows[idx.unique_rows[u as usize]];
            if rep.dense != rows[r].dense || rep.sparse != rows[r].sparse {
                return Err(format!("row {r} mapped to wrong payload"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dedup_tensor_wire_roundtrip_and_expand() {
    check("dedup tensor wire roundtrip", 100, |g| {
        let uniques = g.usize(1..8);
        let nd = g.usize(0..4);
        let dense: Vec<f32> = (0..uniques * nd).map(|_| g.f32()).collect();
        let mut sparse = Vec::new();
        for f in 0..g.usize(0..3) {
            let mut offsets = vec![0u32];
            let mut ids = Vec::new();
            for _ in 0..uniques {
                ids.extend(g.vec_u64(0..1 << 40, 5));
                offsets.push(ids.len() as u32);
            }
            sparse.push((FeatureId(200 + f as u32), offsets, ids));
        }
        let unique = TensorBatch {
            rows: uniques,
            dense,
            dense_names: (0..nd as u32).map(FeatureId).collect(),
            sparse,
            labels: vec![0.0; uniques],
        };
        let rows = g.usize(1..24);
        let inverse: Vec<u32> =
            (0..rows).map(|_| g.u64(0..uniques as u64) as u32).collect();
        let labels: Vec<f32> =
            (0..rows).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
        let db = DedupTensorBatch {
            inverse: inverse.clone(),
            labels: labels.clone(),
            unique,
        };
        let back = DedupTensorBatch::deserialize(&db.serialize())
            .map_err(|e| e.to_string())?;
        if back != db {
            return Err("wire mismatch".into());
        }
        let full = back.expand();
        if full.rows != rows || full.labels != labels {
            return Err("expand shape".into());
        }
        for (i, &u) in inverse.iter().enumerate() {
            for (f, offsets, ids) in &full.sparse {
                let (_, uo, uids) = db
                    .unique
                    .sparse
                    .iter()
                    .find(|(uf, _, _)| uf == f)
                    .ok_or("missing sparse feature")?;
                let got =
                    &ids[offsets[i] as usize..offsets[i + 1] as usize];
                let want = &uids
                    [uo[u as usize] as usize..uo[u as usize + 1] as usize];
                if got != want {
                    return Err(format!("row {i} sparse mismatch"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_coalesce_covers_all_extents_within_window() {
    check("coalesce coverage", 300, |g| {
        let n = g.usize(0..40);
        let mut extents = Vec::new();
        let mut off = 0u64;
        for _ in 0..n {
            off += g.u64(0..5000);
            let len = g.u64(1..3000);
            extents.push(IoRange { offset: off, len });
            off += len;
        }
        let window = g.u64(1000..200_000);
        let ios = coalesce(extents.clone(), Some(window));
        // Every extent fully covered by exactly one I/O.
        for e in &extents {
            let covering = ios
                .iter()
                .filter(|io| e.offset >= io.offset && e.end() <= io.end())
                .count();
            if covering != 1 {
                return Err(format!("extent {e:?} covered by {covering} ios"));
            }
        }
        // No I/O exceeds the window (single extents may).
        for io in &ios {
            if io.len > window
                && !extents
                    .iter()
                    .any(|e| e.offset == io.offset && e.len == io.len)
            {
                return Err(format!("io {io:?} exceeds window {window}"));
            }
        }
        // I/Os are sorted and non-overlapping.
        for w in ios.windows(2) {
            if w[1].offset < w[0].end() {
                return Err("overlapping ios".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partition_round_robin_is_balanced_partition() {
    check("client routing partition", 300, |g| {
        let workers = g.usize(0..50);
        let clients = g.usize(1..10);
        let parts = partition_round_robin(workers, clients);
        let mut seen = vec![0usize; workers];
        for p in &parts {
            for &w in p {
                seen[w] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err("worker not assigned exactly once".into());
        }
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let (mn, mx) = (
            sizes.iter().min().copied().unwrap_or(0),
            sizes.iter().max().copied().unwrap_or(0),
        );
        if mx - mn > 1 {
            return Err(format!("unbalanced: {sizes:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_splits_tile_stripes_exactly() {
    check("split tiling", 300, |g| {
        let stripes: Vec<u32> =
            (0..g.usize(0..30)).map(|_| g.u64(1..500) as u32).collect();
        let per = g.usize(1..8);
        let mut next = g.u64(0..1000);
        let splits =
            splits_for_partition(&mut next, FileId(1), 0, &stripes, per);
        let mut covered = vec![0usize; stripes.len()];
        let mut rows = 0u64;
        for s in &splits {
            for k in s.stripe_start..s.stripe_start + s.stripe_count {
                covered[k] += 1;
            }
            rows += s.rows;
        }
        if covered.iter().any(|&c| c != 1) {
            return Err("stripe not covered exactly once".into());
        }
        let want: u64 = stripes.iter().map(|&r| r as u64).sum();
        if rows != want {
            return Err(format!("row mass {rows} != {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_tensor_batch_wire_roundtrip() {
    check("tensor wire roundtrip", 150, |g| {
        let rows = g.usize(1..20);
        let nd = g.usize(0..5);
        let dense: Vec<f32> = (0..rows * nd).map(|_| g.f32()).collect();
        let mut sparse = Vec::new();
        for f in 0..g.usize(0..4) {
            let mut offsets = vec![0u32];
            let mut ids = Vec::new();
            for _ in 0..rows {
                ids.extend(g.vec_u64(0..1 << 40, 6));
                offsets.push(ids.len() as u32);
            }
            sparse.push((FeatureId(100 + f as u32), offsets, ids));
        }
        let tb = TensorBatch {
            rows,
            dense,
            dense_names: (0..nd as u32).map(FeatureId).collect(),
            sparse,
            labels: (0..rows).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect(),
        };
        let back = TensorBatch::deserialize(&tb.serialize())
            .map_err(|e| e.to_string())?;
        if back != tb {
            return Err("wire mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_transform_ops_preserve_row_count() {
    check("transforms preserve rows", 200, |g| {
        let rows = g.usize(1..30);
        let mut offsets = vec![0u32];
        let mut ids = Vec::new();
        for _ in 0..rows {
            ids.extend(g.vec_u64(0..1 << 20, 8));
            offsets.push(ids.len() as u32);
        }
        let sparse = Value::Sparse {
            offsets,
            ids,
            scores: None,
        };
        let dense = Value::Dense((0..rows).map(|_| g.f32()).collect());
        let ops: Vec<(Op, &Value)> = vec![
            (
                Op::SigridHash {
                    salt: g.u64(0..99),
                    modulus: g.u64(1..1 << 20),
                },
                &sparse,
            ),
            (Op::FirstX { x: g.usize(0..20) }, &sparse),
            (Op::Enumerate, &sparse),
            (
                Op::PositiveModulus {
                    modulus: g.u64(1..1000),
                },
                &sparse,
            ),
            (Op::NGram { n: g.usize(1..4) }, &sparse),
            (Op::Clamp { lo: -1.0, hi: 1.0 }, &dense),
            (Op::Logit { eps: 1e-4 }, &dense),
            (Op::BoxCox { lambda: 0.5 }, &dense),
            (Op::Onehot { buckets: 32 }, &dense),
        ];
        for (op, input) in ops {
            let out = op.apply(&[input]).map_err(|e| e.to_string())?;
            if out.rows() != rows {
                return Err(format!("{} changed rows", op.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_columnar_row_conversion_is_lossless() {
    check("columnar<->rows lossless", 120, |g| {
        let samples = random_samples(g);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let batch =
            ColumnarBatch::from_samples(&samples, &dense_ids, &sparse_ids);
        if batch.to_samples() != samples {
            return Err("conversion lost data".into());
        }
        Ok(())
    });
}

fn random_predicate(g: &mut Gen) -> dsi::filter::RowPredicate {
    use dsi::filter::RowPredicate;
    fn leaf(g: &mut Gen) -> RowPredicate {
        match g.usize(0..4) {
            0 => {
                let a = g.u64(0..1 << 40);
                let b = g.u64(0..1 << 40);
                RowPredicate::TimestampRange {
                    min: a.min(b),
                    max: a.max(b),
                }
            }
            1 => RowPredicate::NegativeDownsample {
                rate: g.usize(0..5) as f64 / 4.0,
                seed: g.u64(0..1000),
            },
            2 => RowPredicate::FeaturePresent {
                feature: FeatureId(g.usize(0..16) as u32),
            },
            _ => RowPredicate::SampleRate {
                rate: g.usize(0..5) as f64 / 4.0,
                seed: g.u64(0..1000),
            },
        }
    }
    if g.bool() {
        leaf(g)
    } else {
        let n = g.usize(1..4);
        RowPredicate::And((0..n).map(|_| leaf(g)).collect())
    }
}

#[test]
fn prop_filtered_plan_covers_surviving_stripes_exactly() {
    use std::collections::HashSet;
    check("filtered plan coverage", 60, |g| {
        let samples = random_samples(g);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let stripe_rows = g.usize(1..16);
        let mut w = DwrfWriter::new(
            "prop",
            dense_ids.clone(),
            sparse_ids.clone(),
            WriterOptions {
                encoding: Encoding::Flattened,
                stripe_rows,
                ..Default::default()
            },
        );
        w.write_all(samples.clone());
        let bytes = w.finish();
        let r = DwrfReader::open_table(&bytes, "prop")
            .map_err(|e| e.to_string())?;
        // Arbitrary projection subset, coalesce window, and predicate.
        let all_ids: Vec<FeatureId> = dense_ids
            .iter()
            .chain(sparse_ids.iter())
            .copied()
            .collect();
        let picked: Vec<FeatureId> =
            all_ids.iter().copied().filter(|_| g.bool()).collect();
        let proj = Projection::new(picked);
        let window = if g.bool() {
            Some(g.u64(1..1 << 21))
        } else {
            None
        };
        let pred = random_predicate(g);
        let plan = r.plan_filtered(&proj, window, Some(&pred));

        // Accounting invariant.
        if plan.useful_bytes > plan.read_bytes {
            return Err(format!(
                "useful {} > read {}",
                plan.useful_bytes, plan.read_bytes
            ));
        }
        // Planned and skipped stripes partition the stripe set.
        let planned: HashSet<usize> =
            plan.stripes.iter().map(|s| s.stripe).collect();
        let skipped: HashSet<usize> =
            plan.skipped_stripes.iter().copied().collect();
        if !planned.is_disjoint(&skipped) {
            return Err("stripe both planned and skipped".into());
        }
        if planned.len() + skipped.len() != r.meta.stripes.len() {
            return Err("stripes lost from the plan".into());
        }
        // Every wanted stream extent of every surviving stripe is
        // covered by exactly that stripe's I/Os; skipped stripes issue
        // none at all.
        for sp in &plan.stripes {
            for &wi in &sp.wanted_streams {
                let st = &r.meta.stripes[sp.stripe].streams[wi];
                let inside = sp.ios.iter().any(|io| {
                    st.offset >= io.offset
                        && st.offset + st.len <= io.end()
                });
                if !inside {
                    return Err(format!(
                        "stream extent uncovered (stripe {})",
                        sp.stripe
                    ));
                }
            }
        }
        // Pruning soundness: a skipped stripe contains no matching row.
        if !skipped.is_empty() {
            let full_proj = Projection::new(all_ids.iter().copied());
            let full_plan = r.plan(&full_proj, None);
            let bufs = r.fetch_local(&bytes, &full_plan);
            for &si in &skipped {
                let rows = r
                    .decode_stripe_rows(
                        si,
                        &bufs,
                        &full_proj,
                        DecodeMode::default(),
                    )
                    .map_err(|e| e.to_string())?;
                if let Some(hit) =
                    rows.iter().find(|s| pred.matches_sample(s))
                {
                    return Err(format!(
                        "pruned stripe {si} had a matching row ts={}",
                        hit.timestamp
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selection_compact_matches_row_filtering() {
    check("selection compaction", 120, |g| {
        let samples = random_samples(g);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let batch =
            ColumnarBatch::from_samples(&samples, &dense_ids, &sparse_ids);
        let pred = random_predicate(g);
        let keep = pred.select_batch(&batch).ones();
        let compacted = batch.with_selection(keep.clone()).compact();
        let want: Vec<_> = samples
            .iter()
            .filter(|s| pred.matches_sample(s))
            .cloned()
            .collect();
        if compacted.num_rows != want.len() {
            return Err(format!(
                "kept {} rows, want {}",
                compacted.num_rows,
                want.len()
            ));
        }
        if compacted.to_samples() != want {
            return Err("selection-compacted rows diverge from \
                        sample-level filtering"
                .into());
        }
        Ok(())
    });
}

#[test]
fn prop_row_group_pruning_is_sound_and_lossless() {
    // For random datasets and predicates, on both the Flattened and
    // Dedup encodings: the rows surviving the *group-pruned* plan +
    // masked decode + row filter are exactly the rows surviving
    // decode-everything-then-filter. Timestamps are made unique so the
    // (window-permuted) dedup output has a canonical order.
    check("row-group pruning soundness", 40, |g| {
        let mut rows = Vec::new();
        for s in &random_samples(g) {
            for _ in 0..g.usize(1..3) {
                let mut c = s.clone();
                c.label = if g.bool() { 1.0 } else { 0.0 };
                rows.push(c);
            }
        }
        for (i, r) in rows.iter_mut().enumerate() {
            r.timestamp = i as u64 * 40 + g.u64(0..40);
        }
        let span = rows.len() as u64 * 40 + 40;
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let stripe_rows = g.usize(4..24);
        let rows_per_group = g.usize(1..8);
        // A timestamp window scaled to the data (the generic 2^40-range
        // generator almost always selects all-or-nothing here), plus
        // the other kinds via conjunction sometimes.
        let a = g.u64(0..span);
        let b = g.u64(0..span);
        let mut pred = dsi::filter::RowPredicate::TimestampRange {
            min: a.min(b),
            max: a.max(b),
        };
        if g.bool() {
            pred = dsi::filter::RowPredicate::And(vec![pred, random_predicate(g)]);
        }
        for encoding in [Encoding::Flattened, Encoding::Dedup] {
            let mut w = DwrfWriter::new(
                "prop",
                dense_ids.clone(),
                sparse_ids.clone(),
                WriterOptions {
                    encoding,
                    stripe_rows,
                    rows_per_group,
                    dedup_window_stripes: 2,
                    ..Default::default()
                },
            );
            w.write_all(rows.clone());
            let bytes = w.finish();
            let r = DwrfReader::open_table(&bytes, "prop")
                .map_err(|e| e.to_string())?;
            let proj = Projection::new(
                dense_ids.iter().chain(sparse_ids.iter()).copied(),
            );
            // Group-pruned path: fetch only the planned extents, honor
            // the per-stripe mask, then row-filter.
            let plan = r.plan_filtered(&proj, None, Some(&pred));
            let bufs = r.fetch_local(&bytes, &plan);
            let mut got = Vec::new();
            for sp in &plan.stripes {
                let decoded = r
                    .decode_stripe_rows_masked(
                        sp.stripe,
                        &bufs,
                        &proj,
                        DecodeMode::default(),
                        sp.group_mask.as_deref(),
                    )
                    .map_err(|e| e.to_string())?;
                got.extend(
                    decoded.into_iter().filter(|s| pred.matches_sample(s)),
                );
            }
            // Baseline: decode everything, then filter.
            let full = r.plan(&proj, None);
            let full_bufs = r.fetch_local(&bytes, &full);
            let mut want = Vec::new();
            for si in 0..r.meta.stripes.len() {
                let decoded = r
                    .decode_stripe_rows(
                        si,
                        &full_bufs,
                        &proj,
                        DecodeMode::default(),
                    )
                    .map_err(|e| e.to_string())?;
                want.extend(
                    decoded.into_iter().filter(|s| pred.matches_sample(s)),
                );
            }
            got.sort_by_key(|s| s.timestamp);
            want.sort_by_key(|s| s.timestamp);
            if got != want {
                return Err(format!(
                    "row-group pruning lost/invented rows: {} vs {} \
                     ({encoding:?}, stripe {stripe_rows}, group \
                     {rows_per_group})",
                    got.len(),
                    want.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_group_masked_plan_never_reads_more() {
    // The group-aware plan's I/O accounting: never more bytes than the
    // stripe-granular plan, and pruned-group rows are consistent with
    // the mask.
    check("group plan accounting", 60, |g| {
        let samples = random_samples(g);
        let dense_ids: Vec<FeatureId> = (0..6).map(FeatureId).collect();
        let sparse_ids: Vec<FeatureId> = (10..15).map(FeatureId).collect();
        let mut w = DwrfWriter::new(
            "prop",
            dense_ids.clone(),
            sparse_ids.clone(),
            WriterOptions {
                encoding: Encoding::Flattened,
                stripe_rows: g.usize(4..20),
                rows_per_group: g.usize(1..6),
                ..Default::default()
            },
        );
        w.write_all(samples.clone());
        let bytes = w.finish();
        let r = DwrfReader::open_table(&bytes, "prop")
            .map_err(|e| e.to_string())?;
        let proj = Projection::new(
            dense_ids.iter().chain(sparse_ids.iter()).copied(),
        );
        let pred = random_predicate(g);
        let n = r.meta.stripes.len();
        let grouped =
            r.plan_stripes_granular(&proj, None, 0, n, Some(&pred), true);
        let striped =
            r.plan_stripes_granular(&proj, None, 0, n, Some(&pred), false);
        if grouped.read_bytes > striped.read_bytes {
            return Err(format!(
                "grouped plan read {} > stripe-only {}",
                grouped.read_bytes, striped.read_bytes
            ));
        }
        if grouped.skipped_stripes.len() < striped.skipped_stripes.len() {
            return Err("group granularity must prune at least as much".into());
        }
        for sp in &grouped.stripes {
            if let Some(mask) = &sp.group_mask {
                let info = &r.meta.stripes[sp.stripe];
                if mask.len() != info.groups.len() {
                    return Err("mask length != group count".into());
                }
                if mask.iter().all(|&k| k) {
                    return Err("all-true mask should have been dropped".into());
                }
            }
        }
        Ok(())
    });
}
