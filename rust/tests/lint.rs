//! dsi-lint against the real tree (must be clean) and against doctored
//! fixtures (must fail, with the right lint at the right file:line) —
//! proving the gate actually gates.
//!
//! v1 invariants always read the real crate sources; the v2 fixture
//! tests point `DSI_LINT_SRC_ROOT` at small doctored trees and run the
//! `conventions`/`concurrency` subcommands, which gate only on v2
//! findings.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

#[test]
fn real_sources_pass_every_repo_check() {
    let errs = dsi::lint::run_repo_checks(env!("CARGO_MANIFEST_DIR"))
        .expect("checker ran");
    assert!(errs.is_empty(), "repo invariants violated: {errs:#?}");
}

#[test]
fn lint_binary_exits_zero_on_real_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .output()
        .expect("spawn dsi-lint");
    assert!(
        out.status.success(),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

/// Add an unfingerprinted, unexempted `PipelineOptions` field to a copy
/// of the real spec and point the binary at it: it must exit non-zero
/// and name the field.
#[test]
fn lint_binary_fails_on_unfingerprinted_field() {
    let real = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/dpp/spec.rs");
    let src = std::fs::read_to_string(&real).expect("read spec.rs");
    let needle = "pub max_frame_bytes: usize,";
    assert!(src.contains(needle), "spec.rs layout changed");
    let doctored = src.replacen(
        needle,
        "pub max_frame_bytes: usize,\n    pub sneaky_knob: bool,",
        1,
    );
    let dir = Path::new(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(dir).expect("tmpdir");
    let path = dir.join("doctored_spec.rs");
    std::fs::write(&path, doctored).expect("write fixture");

    let out = Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .env("DSI_LINT_SPEC_PATH", &path)
        .output()
        .expect("spawn dsi-lint");
    assert_eq!(
        out.status.code(),
        Some(1),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sneaky_knob"), "stderr: {stderr}");
}

/// Write a throwaway source tree under `CARGO_TARGET_TMPDIR`.
fn write_tree(name: &str, files: &[(&str, &str)]) -> PathBuf {
    let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join(name);
    let _ = std::fs::remove_dir_all(&root);
    for (rel, src) in files {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).expect("mkdir");
        std::fs::write(&p, src).expect("write fixture");
    }
    root
}

/// Run the binary's v2 analysis against a fixture tree.
fn run_lint(mode: &str, root: &Path) -> Output {
    Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .arg(mode)
        .env("DSI_LINT_SRC_ROOT", root)
        .output()
        .expect("spawn dsi-lint")
}

/// The doctored tree must exit 1 and name the lint at `loc`
/// (a `file:line` fragment of the finding's location).
fn assert_fails_at(out: &Output, lint: &str, loc: &str) {
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert_eq!(
        out.status.code(),
        Some(1),
        "stdout: {}\nstderr: {stderr}",
        String::from_utf8_lossy(&out.stdout),
    );
    assert!(stderr.contains(lint), "missing [{lint}] in: {stderr}");
    assert!(stderr.contains(loc), "missing {loc} in: {stderr}");
}

/// A small well-behaved tree: sanctioned sync imports, recovering lock
/// helpers, documented `Relaxed`, consistent lock order, and checked
/// wire arithmetic. Every v2 mode must pass it.
#[test]
fn v2_clean_fixture_tree_passes() {
    let root = write_tree(
        "lintfix_clean",
        &[
            (
                "lib.rs",
                r#"use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_or_recover, Mutex};

pub struct Pair {
    first: Mutex<u32>,
    second: Mutex<u32>,
    hits: AtomicU64,
}

impl Pair {
    pub fn ordered(&self) -> u32 {
        let a = lock_or_recover(&self.first, "first");
        let b = lock_or_recover(&self.second, "second");
        // Relaxed: monotone statistics counter, never read for control.
        self.hits.fetch_add(1, Ordering::Relaxed);
        *a + *b
    }
}
"#,
            ),
            (
                "dwrf/ok.rs",
                "pub fn end(offset: u64, len: u64) -> u64 {\n    \
                 offset.checked_add(len).unwrap_or(u64::MAX)\n}\n",
            ),
        ],
    );
    for mode in ["conventions", "concurrency", "graph"] {
        let out = run_lint(mode, &root);
        assert!(
            out.status.success(),
            "{mode}: {}",
            String::from_utf8_lossy(&out.stderr)
        );
    }
}

#[test]
fn doctored_lock_order_cycle_fails() {
    let root = write_tree(
        "lintfix_cycle",
        &[(
            "bad.rs",
            r#"pub struct Pair { left: Mutex<u32>, right: Mutex<u32> }
impl Pair {
    pub fn forward(&self) {
        let _a = lock_or_recover(&self.left, "left");
        let _b = lock_or_recover(&self.right, "right");
    }
    pub fn backward(&self) {
        let _b = lock_or_recover(&self.right, "right");
        let _a = lock_or_recover(&self.left, "left");
    }
}
"#,
        )],
    );
    let out = run_lint("concurrency", &root);
    // The finding anchors at an edge inside the cycle: the second
    // acquisition of `forward`, line 5.
    assert_fails_at(&out, "lock-order-cycle", "bad.rs:5");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("Pair.left -> Pair.right"),
        "cycle members unnamed: {stderr}"
    );
}

#[test]
fn doctored_blocking_under_lock_fails() {
    let root = write_tree(
        "lintfix_blocking",
        &[(
            "bad.rs",
            r#"pub struct Q { state: Mutex<u32> }
pub fn drain(q: &Q, rx: &Receiver<u32>) {
    let _g = lock_or_recover(&q.state, "q state");
    let _v = rx.recv();
}
"#,
        )],
    );
    let out = run_lint("concurrency", &root);
    assert_fails_at(&out, "blocking-under-lock", "bad.rs:4");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("Q.state"), "held lock unnamed: {stderr}");
}

#[test]
fn doctored_std_sync_import_fails() {
    let root = write_tree(
        "lintfix_import",
        &[(
            "bad.rs",
            "use std::sync::Mutex;\npub struct S {\n    m: Mutex<u32>,\n}\n",
        )],
    );
    assert_fails_at(
        &run_lint("conventions", &root),
        "std-sync-import",
        "bad.rs:1",
    );
}

#[test]
fn doctored_bare_lock_unwrap_fails() {
    let root = write_tree(
        "lintfix_unwrap",
        &[(
            "bad.rs",
            "use crate::sync::Mutex;\n\
             pub fn peek(m: &Mutex<u32>) -> u32 {\n    \
             *m.lock().unwrap()\n}\n",
        )],
    );
    assert_fails_at(
        &run_lint("conventions", &root),
        "bare-lock-unwrap",
        "bad.rs:3",
    );
}

#[test]
fn doctored_undocumented_relaxed_fails() {
    let root = write_tree(
        "lintfix_relaxed",
        &[(
            "bad.rs",
            "use crate::sync::atomic::{AtomicU64, Ordering};\n\
             pub fn bump(c: &AtomicU64) {\n    \
             c.fetch_add(1, Ordering::Relaxed);\n}\n",
        )],
    );
    assert_fails_at(
        &run_lint("conventions", &root),
        "undocumented-relaxed",
        "bad.rs:3",
    );
}

#[test]
fn doctored_unchecked_wire_arith_fails() {
    let root = write_tree(
        "lintfix_arith",
        &[(
            "dwrf/bad.rs",
            "pub fn end(offset: u64, len: u64) -> u64 {\n    \
             offset + len\n}\n",
        )],
    );
    assert_fails_at(
        &run_lint("conventions", &root),
        "unchecked-wire-arith",
        "dwrf/bad.rs:2",
    );
}

/// The same arithmetic with a justified allow comment passes — the
/// allowlist mechanism, end to end through the binary.
#[test]
fn justified_allow_suppresses_wire_arith() {
    let root = write_tree(
        "lintfix_allow",
        &[(
            "dwrf/ok.rs",
            "pub fn end(offset: u64, len: u64) -> u64 {\n    \
             // dsi-lint: allow(unchecked-wire-arith): caller validated \
             the extent against the file length.\n    \
             offset + len\n}\n",
        )],
    );
    let out = run_lint("conventions", &root);
    assert!(
        out.status.success(),
        "allow not honored: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

/// `--json` writes the machine-readable report, and the lock-order
/// graph in it covers the real broker/dpp modules.
#[test]
fn json_report_carries_real_lock_graph() {
    let path =
        Path::new(env!("CARGO_TARGET_TMPDIR")).join("dsi_lint_report.json");
    let out = Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .arg("graph")
        .arg("--json")
        .arg(&path)
        .output()
        .expect("spawn dsi-lint");
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let report = std::fs::read_to_string(&path).expect("report written");
    assert!(report.contains("dsi-lint-v2"), "schema tag missing");
    assert!(report.contains("lock_graph"), "graph section missing");
    // Real nodes from the broker and tiering layers.
    for node in ["StripeBuffer.state", "ReadBroker.state", "Master.state"] {
        assert!(report.contains(node), "missing lock node {node}");
    }
}

/// Same fixture, in-process: the violation is exactly the new field.
#[test]
fn doctored_spec_fails_fingerprint_coverage_in_process() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let spec =
        std::fs::read_to_string(root.join("dpp/spec.rs")).expect("spec");
    let cache =
        std::fs::read_to_string(root.join("dpp/cache.rs")).expect("cache");
    let doctored = spec.replacen(
        "pub max_frame_bytes: usize,",
        "pub max_frame_bytes: usize,\n    pub sneaky_knob: bool,",
        1,
    );
    let errs = dsi::lint::check_fingerprint_coverage(&doctored, &cache);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert!(errs[0].contains("sneaky_knob"), "{errs:?}");
}
