//! dsi-lint against the real tree (must be clean) and against a
//! doctored fixture (must fail) — proving the gate actually gates.

use std::path::Path;
use std::process::Command;

#[test]
fn real_sources_pass_every_repo_check() {
    let errs = dsi::lint::run_repo_checks(env!("CARGO_MANIFEST_DIR"))
        .expect("checker ran");
    assert!(errs.is_empty(), "repo invariants violated: {errs:#?}");
}

#[test]
fn lint_binary_exits_zero_on_real_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .output()
        .expect("spawn dsi-lint");
    assert!(
        out.status.success(),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
}

/// Add an unfingerprinted, unexempted `PipelineOptions` field to a copy
/// of the real spec and point the binary at it: it must exit non-zero
/// and name the field.
#[test]
fn lint_binary_fails_on_unfingerprinted_field() {
    let real = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/dpp/spec.rs");
    let src = std::fs::read_to_string(&real).expect("read spec.rs");
    let needle = "pub max_frame_bytes: usize,";
    assert!(src.contains(needle), "spec.rs layout changed");
    let doctored = src.replacen(
        needle,
        "pub max_frame_bytes: usize,\n    pub sneaky_knob: bool,",
        1,
    );
    let dir = Path::new(env!("CARGO_TARGET_TMPDIR"));
    std::fs::create_dir_all(dir).expect("tmpdir");
    let path = dir.join("doctored_spec.rs");
    std::fs::write(&path, doctored).expect("write fixture");

    let out = Command::new(env!("CARGO_BIN_EXE_dsi-lint"))
        .env("DSI_LINT_SPEC_PATH", &path)
        .output()
        .expect("spawn dsi-lint");
    assert_eq!(
        out.status.code(),
        Some(1),
        "stdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("sneaky_knob"), "stderr: {stderr}");
}

/// Same fixture, in-process: the violation is exactly the new field.
#[test]
fn doctored_spec_fails_fingerprint_coverage_in_process() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let spec =
        std::fs::read_to_string(root.join("dpp/spec.rs")).expect("spec");
    let cache =
        std::fs::read_to_string(root.join("dpp/cache.rs")).expect("cache");
    let doctored = spec.replacen(
        "pub max_frame_bytes: usize,",
        "pub max_frame_bytes: usize,\n    pub sneaky_knob: bool,",
        1,
    );
    let errs = dsi::lint::check_fingerprint_coverage(&doctored, &cache);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert!(errs[0].contains("sneaky_knob"), "{errs:?}");
}
