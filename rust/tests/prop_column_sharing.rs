//! Property: column-grain sharing is lossless under *any* partition of
//! a session's projection into cached-wider vs fresh columns. A warmer
//! session with a random projection populates the broker's column
//! cache; a target session with another random projection (overlapping
//! arbitrarily — subset, superset, disjoint, or partial) then serves
//! some columns from the warmer's wider decode and fetches the rest,
//! and its wire output must be byte-identical to a private scan — for
//! Flattened and Dedup encodings, with and without row predicates.
//! (Random data via the in-repo `util::prop` mini-harness; proptest is
//! unavailable offline.)

use dsi::broker::ReadBroker;
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::{Encoding, WriterOptions};
use dsi::filter::RowPredicate;
use dsi::metrics::EtlMetrics;
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::prop::{check, Gen};
use dsi::warehouse::Catalog;
use std::sync::Arc;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    table: String,
    /// (feature, is_dense) for every materialized feature.
    features: Vec<(FeatureId, bool)>,
}

fn build(encoding: Encoding, dup_factor: usize) -> World {
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 64 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale::tiny();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 16,
            ..Default::default()
        },
        31,
        &GenOptions {
            dup_factor,
            tick_max: 40, // spread timestamps so recency cuts bite
            ..Default::default()
        },
    )
    .unwrap();
    let features = h
        .schema
        .features
        .iter()
        .map(|f| (f.id, matches!(f.kind, FeatureKind::Dense)))
        .collect();
    World {
        cluster,
        catalog,
        table: h.table_name,
        features,
    }
}

/// The same per-feature normalization chain for every session, so a
/// projection alone defines the session.
fn spec_for(world: &World, proj: &[FeatureId]) -> SessionSpec {
    let mut dag = TransformDag::default();
    for &fid in proj {
        let dense = world
            .features
            .iter()
            .find(|(id, _)| *id == fid)
            .map(|(_, d)| *d)
            .unwrap_or(false);
        if dense {
            let i = dag.input_dense(fid);
            let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
            dag.output(fid, c);
        } else {
            let i = dag.input_sparse(fid);
            let s = dag.apply(
                Op::SigridHash {
                    salt: 5,
                    modulus: 1 << 12,
                },
                vec![i],
            );
            dag.output(fid, s);
        }
    }
    SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, 8)
}

type Wire = Vec<(u64, usize, bool, Vec<u8>)>;

/// Build (and, for brokered sessions, *register*) a session without
/// draining it — registration order decides whose interest keeps the
/// peer's columns cached.
fn session(
    world: &World,
    spec: SessionSpec,
    broker: Option<&Arc<ReadBroker>>,
) -> (Master, WorkerCore) {
    let mut spec = spec;
    spec.pipeline.shared_reads = broker.is_some();
    let master = match broker {
        Some(b) => Master::new_shared(
            &world.catalog,
            &world.cluster,
            spec.clone(),
            b,
        ),
        None => Master::new(&world.catalog, &world.cluster, spec.clone()),
    }
    .unwrap();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(Arc::new(spec), world.cluster.clone(), metrics);
    if let Some(h) = master.broker_handle() {
        core = core.with_broker(h);
    }
    (master, core)
}

fn drain(master: Master, mut core: WorkerCore) -> Wire {
    let w = master.register_worker();
    let mut wire = Wire::new();
    while let Some(split) = master.fetch_split(w) {
        for b in core.process_split(&split).unwrap() {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        master.complete_split(w, split.id);
    }
    wire
}

/// One random case: draw warmer/target projections feature by feature
/// (both, warmer-only, target-only, neither), optionally predicate the
/// target, warm the column cache, and demand byte-identity.
fn column_partition_case(
    world: &World,
    g: &mut Gen,
) -> Result<(), String> {
    let mut warm: Vec<FeatureId> = Vec::new();
    let mut target: Vec<FeatureId> = Vec::new();
    for &(fid, _) in &world.features {
        match g.u64(0..4) {
            0 => warm.push(fid),
            1 => target.push(fid),
            2 => {
                warm.push(fid);
                target.push(fid);
            }
            _ => {}
        }
    }
    // Both sessions need at least one output.
    if warm.is_empty() {
        warm.push(world.features[0].0);
    }
    if target.is_empty() {
        target.push(world.features[world.features.len() - 1].0);
    }
    let warm_spec = spec_for(world, &warm);
    let mut target_spec = spec_for(world, &target);
    target_spec = match g.u64(0..3) {
        0 => target_spec,
        1 => target_spec.with_predicate(RowPredicate::TimestampRange {
            min: 0,
            max: g.u64(1..40),
        }),
        _ => target_spec.with_predicate(RowPredicate::SampleRate {
            rate: 0.5,
            seed: g.u64(0..1000),
        }),
    };

    // Private reference for the target session.
    let (bm, bc) = session(world, target_spec.clone(), None);
    let base = drain(bm, bc);

    // Both sessions register before the warmer drains, so the target's
    // outstanding interest keeps the warmer's columns cached.
    let broker =
        ReadBroker::with_budget_bytes(world.cluster.clone(), 64 << 20);
    let (wm, wc) = session(world, warm_spec, Some(&broker));
    let (tm, tc) = session(world, target_spec, Some(&broker));
    let warm_wire = drain(wm, wc);
    if warm_wire.is_empty() {
        return Err("warmer session produced no wire".into());
    }
    let got = drain(tm, tc);

    if got != base {
        return Err(format!(
            "wire diverged: warm proj {warm:?}, target proj {target:?}, \
             {} vs {} batches",
            got.len(),
            base.len()
        ));
    }
    // The row-meta column alone guarantees the target hit the cache.
    if broker.metrics.column_hits.get() == 0 {
        return Err("target session never hit the column cache".into());
    }
    // Both sessions consumed their registered interest: nothing stays
    // resident or charged.
    if broker.buffered_columns() != 0 || broker.budget().used() != 0 {
        return Err(format!(
            "column cache leaked: {} columns, {} bytes",
            broker.buffered_columns(),
            broker.budget().used()
        ));
    }
    Ok(())
}

#[test]
fn prop_column_partition_lossless_flattened() {
    let world = build(Encoding::Flattened, 1);
    check("column_partition_flattened", 12, |g| {
        column_partition_case(&world, g)
    });
}

#[test]
fn prop_column_partition_lossless_dedup() {
    let world = build(Encoding::Dedup, 3);
    check("column_partition_dedup", 12, |g| {
        column_partition_case(&world, g)
    });
}
