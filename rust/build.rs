fn main() {
    // `--cfg loom` swaps `dsi::sync` onto the instrumented shim for
    // model checking (see src/sync). Declare it so `unexpected_cfgs`
    // stays quiet on normal builds.
    println!("cargo:rustc-check-cfg=cfg(loom)");
}
