//! dsi-lint — repo-invariant gate (see `dsi::lint` for the checks).
//!
//! Exit codes: 0 = all invariants hold, 1 = violations, 2 = the checker
//! itself failed (missing source file, bad `DSI_LINT_SPEC_PATH`, ...).

fn main() {
    match dsi::lint::run_repo_checks(env!("CARGO_MANIFEST_DIR")) {
        Ok(errs) if errs.is_empty() => {
            println!("dsi-lint: all repo invariants hold");
        }
        Ok(errs) => {
            for e in &errs {
                eprintln!("dsi-lint: {e}");
            }
            eprintln!("dsi-lint: {} violation(s)", errs.len());
            std::process::exit(1);
        }
        Err(e) => {
            eprintln!("dsi-lint: error: {e:#}");
            std::process::exit(2);
        }
    }
}
