//! dsi-lint — repo invariant + concurrency-convention gate (see
//! `dsi::lint` for the checks).
//!
//! ```text
//! dsi-lint [SUBCOMMAND] [--json PATH]
//!
//!   all           v1 invariants + v2 analysis (default)
//!   invariants    v1 fingerprint/clock/merge coverage only
//!   conventions   v2 convention lints (std::sync hygiene, bare lock
//!                 unwraps, undocumented Relaxed, wire arithmetic)
//!   concurrency   v2 guard-scope / lock-order / blocking-under-lock
//!   graph         print the crate lock-order graph, no lints
//!
//!   --json PATH   also write the machine-readable findings report
//! ```
//!
//! `DSI_LINT_SRC_ROOT` points the v2 analysis at an alternate source
//! tree (fixture tests); `DSI_LINT_SPEC_PATH` overrides the v1 spec
//! file. Exit codes: 0 = clean, 1 = findings, 2 = the checker itself
//! failed (missing source file, bad flag, unwritable report, ...).

use dsi::lint;

fn main() {
    let mut mode = String::from("all");
    let mut json_path: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "all" | "invariants" | "conventions" | "concurrency"
            | "graph" => mode = a,
            "--json" => match args.next() {
                Some(p) => json_path = Some(p),
                None => fail2("--json needs a path"),
            },
            other => fail2(&format!("unknown argument `{other}`")),
        }
    }

    let manifest = env!("CARGO_MANIFEST_DIR");
    let run_v1 = matches!(mode.as_str(), "all" | "invariants");
    let run_v2 = matches!(
        mode.as_str(),
        "all" | "conventions" | "concurrency" | "graph"
    );

    // v1 invariants always run against the real crate sources (the
    // DSI_LINT_SPEC_PATH hook still applies); the v2 analysis honors
    // DSI_LINT_SRC_ROOT so fixtures can doctor a whole tree.
    let invariant_errs = if run_v1 {
        match lint::run_repo_checks(manifest) {
            Ok(errs) => errs,
            Err(e) => fail2(&format!("{e:#}")),
        }
    } else {
        Vec::new()
    };

    let analysis = if run_v2 {
        match lint::run_analysis(manifest) {
            Ok(a) => a,
            Err(e) => fail2(&format!("{e:#}")),
        }
    } else {
        lint::Analysis {
            findings: Vec::new(),
            graph: Default::default(),
        }
    };

    // `conventions` and `concurrency` narrow which v2 findings gate;
    // the report always carries the full set it computed.
    let conc_lints =
        ["lock-order-cycle", "blocking-under-lock"];
    let gating: Vec<&lint::Finding> = analysis
        .findings
        .iter()
        .filter(|f| match mode.as_str() {
            "conventions" => !conc_lints.contains(&f.lint.as_str()),
            "concurrency" => conc_lints.contains(&f.lint.as_str()),
            "graph" => false,
            _ => true,
        })
        .collect();

    if let Some(path) = &json_path {
        let report = lint::report_json(&analysis, &invariant_errs);
        if let Err(e) = std::fs::write(path, report.to_string_pretty()) {
            fail2(&format!("writing {path}: {e}"));
        }
    }

    if mode == "graph" {
        for (name, ctxs) in &analysis.graph.nodes {
            let mut cs: Vec<&str> =
                ctxs.iter().map(String::as_str).collect();
            cs.sort_unstable();
            println!("node {name} [{}]", cs.join(", "));
        }
        for e in &analysis.graph.edges {
            let via = e
                .via
                .as_deref()
                .map(|v| format!(" via {v}"))
                .unwrap_or_default();
            println!(
                "edge {} -> {}{via} (src/{}:{})",
                e.from, e.to, e.file, e.line
            );
        }
    }

    for e in &invariant_errs {
        eprintln!("dsi-lint: {e}");
    }
    for f in &gating {
        eprintln!("dsi-lint: {f}");
    }
    let total = invariant_errs.len() + gating.len();
    if total > 0 {
        eprintln!("dsi-lint: {total} violation(s)");
        std::process::exit(1);
    }
    if mode != "graph" {
        println!(
            "dsi-lint: clean ({} lock nodes, {} lock-order edges)",
            analysis.graph.nodes.len(),
            analysis.graph.edges.len()
        );
    }
}

fn fail2(msg: &str) -> ! {
    eprintln!("dsi-lint: error: {msg}");
    std::process::exit(2);
}
