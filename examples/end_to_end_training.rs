//! End-to-end driver: proves all three layers compose.
//!
//! 1. Offline path (Rust): serving sim → Scribe → ETL join → DWRF
//!    partitions in the Tectonic cluster.
//! 2. Online path (Rust, L3): a DPP session — Master splits, Workers
//!    extract/transform/load, Client receives wire tensors.
//! 3. Training (L2/L1 via PJRT): every DPP tensor batch is adapted to
//!    the AOT-compiled DLRM (JAX + Pallas kernels, HLO-text artifacts)
//!    and drives real fwd+bwd+SGD steps. The loss curve is logged.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example end_to_end_training
//! ```

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::{Client, Master, PipelineOptions, SessionSpec, Worker};
use dsi::dwrf::{Projection, WriterOptions};
use dsi::metrics::EtlMetrics;
use dsi::runtime::{artifacts_available, artifacts_dir, DlrmBatch, DlrmRuntime};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = DlrmRuntime::load(&artifacts_dir())?;
    println!(
        "DLRM runtime: {} params, batch {}, vocab {}",
        rt.manifest.num_params, rt.manifest.batch, rt.manifest.vocab
    );

    // ---- offline data generation ----
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 4096,
        materialized_features: 192,
        partitions: 3,
    };
    let mut rng = Pcg32::new(7);
    let cluster = Arc::new(Cluster::new(ClusterConfig::default()));
    let catalog = Catalog::new();
    let ds = build_dataset(&cluster, &catalog, &rm, &scale, WriterOptions::default(), 7)?;
    println!(
        "dataset: {} rows across {} partitions",
        catalog.get(&ds.table_name).unwrap().total_rows(),
        scale.partitions
    );

    // ---- DPP session ----
    let take =
        (ds.schema.features.len() as f64 * rm.frac_feats_used()).round() as usize;
    let projection =
        ds.schema
            .sample_projection(&mut rng, take.max(24), rm.popularity_zipf_s);
    let dag = session_dag(&mut rng, &rm, &ds.schema, &projection);
    let mut spec = SessionSpec::from_dag(
        &ds.table_name,
        0,
        u32::MAX,
        dag,
        rt.manifest.batch,
    );
    spec.projection = Projection::new(projection.iter().copied());
    spec.pipeline = PipelineOptions::default();
    let spec = Arc::new(spec);

    let master = Arc::new(Master::new(&catalog, &cluster, (*spec).clone())?);
    let metrics = Arc::new(EtlMetrics::default());
    let (tx1, rx1) = std::sync::mpsc::sync_channel(32);
    let (tx2, rx2) = std::sync::mpsc::sync_channel(32);
    let w1 = Worker::spawn(master.clone(), cluster.clone(), spec.clone(), metrics.clone(), tx1);
    let w2 = Worker::spawn(master.clone(), cluster.clone(), spec.clone(), metrics.clone(), tx2);
    let mut client = Client::new(&spec.table, vec![rx1, rx2]);

    // ---- training loop: DPP tensors → PJRT DLRM train steps ----
    let mut params = rt.init_params(7)?;
    let mut step = 0u64;
    let mut losses: Vec<f32> = Vec::new();
    let t0 = std::time::Instant::now();
    while let Some(tb) = client.next_batch(Duration::from_secs(30))? {
        let batch = DlrmBatch::from_tensor_batch(&tb, &rt.manifest);
        let (p, loss) = rt.train_step(params, &batch)?;
        params = p;
        losses.push(loss);
        if step % 25 == 0 {
            println!("step {step:>5}  loss {loss:.4}");
        }
        step += 1;
    }
    w1.join();
    w2.join();
    let dt = t0.elapsed().as_secs_f64();

    let head: f32 =
        losses.iter().take(10).sum::<f32>() / losses.len().min(10) as f32;
    let tail: f32 = losses.iter().rev().take(10).sum::<f32>()
        / losses.len().min(10) as f32;
    println!("---");
    println!(
        "trained {} steps ({} samples) in {:.1}s — {:.1} steps/s",
        step,
        step * rt.manifest.batch as u64,
        dt,
        step as f64 / dt
    );
    println!(
        "loss: first-10 avg {head:.4} → last-10 avg {tail:.4} ({})",
        if tail < head {
            "descending ✓"
        } else {
            "NOT descending ✗"
        }
    );
    println!(
        "client stalled {:.2}s total waiting on DPP (data stalls)",
        client.stalled()
    );
    println!(
        "worker pipeline: {:.0} rows/s busy throughput; storage {:.1} MB \
         fetched",
        metrics.qps(),
        metrics.storage_rx_bytes.get() as f64 / 1e6
    );
    assert!(step > 0, "no batches delivered");
    Ok(())
}
