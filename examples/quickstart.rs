//! Quickstart: the whole DSI pipeline in ~60 lines.
//!
//! Generates a small RM3-shaped dataset through the offline path
//! (serving sim → Scribe → ETL → DWRF files in Tectonic), then runs a
//! DPP session (Master + Workers + Client) and prints what came out.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset;
use dsi::dpp::{Session, SessionConfig, SessionSpec};
use dsi::dwrf::WriterOptions;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let rm = RmConfig::get(RmId::Rm3);
    let scale = SimScale::standard();
    let mut rng = Pcg32::new(42);

    // 1. Offline data generation: samples land as DWRF partitions in the
    //    Tectonic cluster and register in the warehouse catalog.
    let cluster = Arc::new(Cluster::new(ClusterConfig::default()));
    let catalog = Catalog::new();
    let ds = build_dataset(&cluster, &catalog, &rm, &scale, WriterOptions::default(), 42)?;
    let table = catalog.get(&ds.table_name).unwrap();
    println!(
        "dataset: {} partitions, {} rows, {} stored bytes (3x replicated: {})",
        table.partitions.len(),
        table.total_rows(),
        table.total_bytes(),
        cluster.stored_bytes(),
    );

    // 2. A training job's session spec: feature projection + transform DAG.
    let take = (ds.schema.features.len() as f64 * rm.frac_feats_used()).round() as usize;
    let projection = ds.schema.sample_projection(&mut rng, take, rm.popularity_zipf_s);
    println!(
        "projection: {} of {} features ({}%)",
        projection.len(),
        ds.schema.features.len(),
        projection.len() * 100 / ds.schema.features.len()
    );
    let dag = session_dag(&mut rng, &rm, &ds.schema, &projection);
    let spec = SessionSpec::from_dag(&ds.table_name, 0, u32::MAX, dag, 64);

    // 3. Run DPP: Master shards the read into splits; Workers extract,
    //    transform, and load; the Client receives ready-to-train tensors.
    let report = Session::run(
        &catalog,
        &cluster,
        spec,
        &SessionConfig {
            initial_workers: 2,
            max_workers: 4,
            clients: 1,
            ..Default::default()
        },
    )?;
    println!(
        "DPP session: {} rows in {:.2}s ({:.0} rows/s), {} tensor batches",
        report.rows_delivered,
        report.wall_secs,
        report.rows_per_sec,
        report.batches_delivered,
    );
    println!(
        "storage: {} reads / {} seeks, {:.1} MB fetched, {:.1} MB/s per device-sec",
        report.storage_reads,
        report.storage_seeks,
        report.storage_bytes_read as f64 / 1e6,
        report.storage_mbps(),
    );
    Ok(())
}
