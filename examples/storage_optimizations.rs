//! Walkthrough of the paper's §7.5 co-designed storage optimizations
//! (Table 12): runs the real pipeline under each progressive
//! configuration and prints the throughput story stage by stage.
//!
//! ```bash
//! cargo run --release --example storage_optimizations
//! ```

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::dwrf::WriterOptions;
use dsi::paper::harness::{build_world, measure_pipeline, popularity_order};
use dsi::paper::storage::table12_stages;

fn main() -> anyhow::Result<()> {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale::standard();
    let seed = 42;

    println!("Table 12 walkthrough — RM1-shaped dataset, real pipeline\n");
    let probe = build_world(
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 128,
            ..Default::default()
        },
        seed,
    )?;
    let order = popularity_order(&probe);

    let mut base_dpp = None;
    let mut base_storage = None;
    for (name, encoding, reorder, pipeline, _, stripe_mult) in table12_stages() {
        let writer = WriterOptions {
            encoding,
            stripe_rows: 128 * stripe_mult,
            feature_order: if reorder { Some(order.clone()) } else { None },
            ..Default::default()
        };
        let world = build_world(&rm, &scale, writer, seed)?;
        let m = measure_pipeline(&world, pipeline, 64, seed)?;
        let dpp0 = *base_dpp.get_or_insert(m.worker_sps);
        let st0 = *base_storage.get_or_insert(m.storage_mbps);
        println!(
            "{:<9} DPP {:>8.0} rows/s ({:>5.2}x) | storage {:>9.1} MB/s \
             ({:>5.2}x) | {:>6} I/Os, {:>6} seeks, over-read {:>4.2}x",
            name,
            m.worker_sps,
            m.worker_sps / dpp0,
            m.storage_mbps,
            m.storage_mbps / st0,
            m.storage.reads,
            m.storage.seeks,
            m.storage.bytes_read as f64 / m.storage_rx_bytes.max(1) as f64,
        );
        match name {
            "Baseline" => println!("          ^ map encoding: big sequential reads, but decodes every feature"),
            "+FF" => println!("          ^ feature flattening: reads only projected features — small I/Os crater HDD throughput"),
            "+FM" => println!("          ^ in-memory flatmap: no row-map reconstruction"),
            "+LO" => println!("          ^ localized opts: branch-lean decode inner loops"),
            "+CR" => println!("          ^ coalesced reads: ≤1.25MiB windows amortize seeks (over-reads gaps)"),
            "+FR" => println!("          ^ feature reordering: popular features adjacent — less over-read"),
            "+LS" => println!("          ^ large stripes: longer feature streams per seek"),
            _ => {}
        }
    }
    println!(
        "\npaper reference: DPP 1.00→2.00→2.30→2.94 (flat after); storage \
         1.00→0.03→0.03→0.03→0.99→1.84→2.41"
    );
    println!(
        "note: this walkthrough runs at a small interactive scale; the \
         calibrated production-regime reproduction (wide stripes, 1k \
         features) is `dsi paper --exp table12`."
    );
    Ok(())
}
