//! Coordinated training at scale (§4): simulate the collaborative
//! release process, a year of global utilization, and regional
//! placement with bin-packing (Figs 4–6, §7.3).
//!
//! ```bash
//! cargo run --release --example global_scheduler
//! ```

use dsi::metrics::Series;
use dsi::sched::{
    combo_iteration, daily_utilization, model_release_jobs, place_balanced,
    place_packed, top10_model_demand, JobStatus, REGIONS,
};
use dsi::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::new(2026);

    // ---- Fig 4: one release iteration ----
    let jobs = combo_iteration(&mut rng, 0, 82, 10.0);
    let completed = jobs.iter().filter(|j| j.status == JobStatus::Completed).count();
    println!("release iteration: 82 combo jobs → {completed} completed");
    let mut starts: Vec<f64> = jobs.iter().map(|j| j.start).collect();
    starts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "temporal skew: half the jobs launch within the first {:.1} of {:.0} days",
        starts[jobs.len() / 2],
        10.0
    );

    // ---- Fig 5: a year of collaborative training ----
    let mut all_jobs = Vec::new();
    for m in 0..40 {
        let scale = 1.0 / (m as f64 + 1.0).powf(0.7);
        all_jobs.extend(model_release_jobs(&mut rng, m, 365.0, 40.0, scale));
    }
    let days = daily_utilization(&all_jobs, 365);
    let mut s = Series::new("util");
    for (d, &u) in days.iter().enumerate() {
        s.push(d as f64, u);
    }
    println!("\nyear of training ({} jobs):", all_jobs.len());
    println!("  {}", s.normalized().sparkline(72));
    let mean = days.iter().sum::<f64>() / days.len() as f64;
    let peak = days.iter().cloned().fold(0.0f64, f64::max);
    println!("  peak/mean = {:.2} → provision datacenters for combo peaks", peak / mean);

    // ---- Fig 6 + §7.3: regional placement ----
    let demand = top10_model_demand();
    let balanced = place_balanced(&mut rng, &demand);
    let total: f64 = demand.iter().sum();
    println!("\ntop-10 models demand (normalized to J): {:?}",
        demand.iter().map(|d| (d * 100.0).round() / 100.0).collect::<Vec<_>>());
    for cap_factor in [1.1, 1.25, 1.5] {
        let packed = place_packed(&demand, total / REGIONS as f64 * cap_factor);
        println!(
            "  capacity {:.0}% of even-split: balanced {} dataset copies → \
             packed {} (−{:.0}%)",
            cap_factor * 100.0,
            balanced.dataset_copies,
            packed.dataset_copies,
            (1.0 - packed.dataset_copies as f64 / balanced.dataset_copies as f64)
                * 100.0
        );
    }
    println!(
        "\n§7.3: a global scheduler that bin-packs jobs to regions cuts \
         dataset replication while respecting peak demand."
    );
}
