"""L2 correctness: DLRM model shapes, loss behaviour, and the AOT
entrypoints' (fwd / train_step) agreement with an all-reference model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import (bce_with_logits_ref, dense_xform_ref,
                                 embedding_bag_ref, interaction_ref,
                                 matmul_bias_relu_ref)
from compile.model import (CFG, PARAM_NAMES, batch_spec, forward, fwd_loss,
                           init_params, loss_fn, num_params, param_shapes,
                           train_step)

jax.config.update("jax_platform_name", "cpu")


def make_batch(seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    dense = jax.random.normal(k1, (CFG.batch, CFG.n_dense), jnp.float32)
    ids = jax.random.randint(
        k2, (CFG.batch, CFG.n_sparse, CFG.ids_per_feature), 0, CFG.vocab
    )
    mask = (
        jax.random.uniform(k3, (CFG.batch, CFG.n_sparse, CFG.ids_per_feature))
        < 0.8
    ).astype(jnp.float32)
    labels = (dense[:, 0] > 0).astype(jnp.float32)
    return dense, ids, mask, labels


def reference_forward(params, dense, ids, mask):
    """The whole model with reference ops only (no Pallas)."""
    emb, w1, b1, w2, b2, wt1, bt1, wt2, bt2 = params
    mean = jnp.zeros((CFG.n_dense,), jnp.float32)
    std = 2.0 * jnp.ones((CFG.n_dense,), jnp.float32)
    x = dense_xform_ref(dense, mean, std)
    h = matmul_bias_relu_ref(x, w1, b1, relu=True)
    bottom = matmul_bias_relu_ref(h, w2, b2, relu=False)
    pooled = embedding_bag_ref(emb, ids, mask)
    inter = interaction_ref(bottom, pooled)
    top_in = jnp.concatenate([bottom, inter], axis=1)
    h2 = matmul_bias_relu_ref(top_in, wt1, bt1, relu=True)
    return matmul_bias_relu_ref(h2, wt2, bt2, relu=False)[:, 0]


def test_param_shapes_consistent():
    assert len(PARAM_NAMES) == len(param_shapes())
    params = init_params(jax.random.PRNGKey(0))
    for p, shape in zip(params, param_shapes()):
        assert p.shape == shape
    total = sum(int(np.prod(s)) for s in param_shapes())
    assert total == num_params()


def test_forward_shape_and_finiteness():
    params = init_params(jax.random.PRNGKey(1))
    dense, ids, mask, labels = make_batch(1)
    logits = forward(params, dense, ids, mask)
    assert logits.shape == (CFG.batch,)
    assert bool(jnp.isfinite(logits).all())
    loss = loss_fn(params, dense, ids, mask, labels)
    assert bool(jnp.isfinite(loss))


def test_pallas_model_matches_reference_model():
    params = init_params(jax.random.PRNGKey(2))
    dense, ids, mask, _ = make_batch(2)
    got = forward(params, dense, ids, mask)
    want = reference_forward(params, dense, ids, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwd_loss_entry_matches_loss_fn():
    params = init_params(jax.random.PRNGKey(3))
    dense, ids, mask, labels = make_batch(3)
    loss_a, logits = fwd_loss((*params, dense, ids, mask, labels))
    loss_b = loss_fn(params, dense, ids, mask, labels)
    assert float(loss_a) == pytest.approx(float(loss_b), rel=1e-6)
    ref = bce_with_logits_ref(logits, labels)
    assert float(loss_a) == pytest.approx(float(ref), rel=1e-5)


def test_train_step_descends_on_fixed_batch():
    params = init_params(jax.random.PRNGKey(4))
    dense, ids, mask, labels = make_batch(4)
    step = jax.jit(train_step)
    loss0 = float(loss_fn(params, dense, ids, mask, labels))
    p = params
    losses = []
    for _ in range(40):
        out = step(*p, dense, ids, mask, labels)
        p, loss = out[:-1], out[-1]
        losses.append(float(loss))
    assert losses[-1] < loss0 * 0.92, f"{loss0} -> {losses[-1]}"
    # Monotone-ish: strictly below start for the whole back half.
    assert all(l < loss0 for l in losses[20:])


def test_train_step_generalizes_across_batches():
    params = init_params(jax.random.PRNGKey(5))
    step = jax.jit(train_step)
    p = params
    losses = []
    for s in range(50):
        dense, ids, mask, labels = make_batch(100 + s)
        out = step(*p, dense, ids, mask, labels)
        p, loss = out[:-1], out[-1]
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.97, losses


def test_gradients_match_reference_model_gradients():
    params = init_params(jax.random.PRNGKey(6))
    dense, ids, mask, labels = make_batch(6)

    def loss_pallas(p):
        return loss_fn(p, dense, ids, mask, labels)

    def loss_ref(p):
        logits = reference_forward(p, dense, ids, mask)
        return bce_with_logits_ref(logits, labels)

    gp = jax.grad(loss_pallas)(params)
    gr = jax.grad(loss_ref)(params)
    for name, a, b in zip(PARAM_NAMES, gp, gr):
        np.testing.assert_allclose(
            a, b, rtol=1e-3, atol=1e-5, err_msg=f"grad mismatch: {name}"
        )


def test_batch_spec_matches_make_batch():
    specs = batch_spec()
    batch = make_batch(7)
    for spec, arr in zip(specs, batch):
        assert spec.shape == arr.shape
        assert spec.dtype == arr.dtype
