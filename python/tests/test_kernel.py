"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including non-block-aligned ones) and value
regimes; assert_allclose against the reference is THE core correctness
signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.dense_xform import dense_xform, BLOCK_B, BLOCK_D
from compile.kernels.mlp import matmul_bias_relu, mxu_utilization_estimate
from compile.kernels.ref import (bce_with_logits_ref, dense_xform_ref,
                                 embedding_bag_ref, interaction_ref,
                                 matmul_bias_relu_ref)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------
# dense_xform kernel
# ---------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=40),
    d=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 100.0]),
)
def test_dense_xform_matches_ref(b, d, seed, scale):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (b, d), scale)
    mean = rand(k2, (d,))
    std = jnp.abs(rand(k3, (d,))) + 0.1
    got = dense_xform(x, mean, std)
    want = dense_xform_ref(x, mean, std)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_dense_xform_exact_block_shape():
    key = jax.random.PRNGKey(0)
    x = rand(key, (BLOCK_B * 2, BLOCK_D))
    mean = jnp.zeros((BLOCK_D,))
    std = jnp.ones((BLOCK_D,))
    np.testing.assert_allclose(
        dense_xform(x, mean, std),
        dense_xform_ref(x, mean, std),
        rtol=1e-6,
    )


def test_dense_xform_clamps_extremes():
    x = jnp.array([[1e30, -1e30]], jnp.float32)
    mean = jnp.zeros((2,))
    std = jnp.full((2,), 0.1, jnp.float32)
    y = dense_xform(x, mean, std)
    assert float(y[0, 0]) == 8.0
    assert float(y[0, 1]) == -8.0


def test_dense_xform_grad_matches_ref_grad():
    key = jax.random.PRNGKey(3)
    x = rand(key, (9, 33))
    mean = jnp.zeros((33,))
    std = jnp.ones((33,)) * 1.5

    def f_kernel(x):
        return dense_xform(x, mean, std).sum()

    def f_ref(x):
        return dense_xform_ref(x, mean, std).sum()

    gk = jax.grad(f_kernel)(x)
    gr = jax.grad(f_ref)(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------
# MLP (tiled matmul) kernel
# ---------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=70),
    k=st.integers(min_value=1, max_value=96),
    n=st.integers(min_value=1, max_value=150),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, relu, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (m, k))
    w = rand(k2, (k, n))
    b = rand(k3, (n,))
    got = matmul_bias_relu(x, w, b, relu=relu)
    want = matmul_bias_relu_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_grads_match_ref():
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (17, 23))
    w = rand(k2, (23, 31))
    b = rand(k3, (31,))

    def f_kernel(x, w, b):
        return (matmul_bias_relu(x, w, b, relu=True) ** 2).sum()

    def f_ref(x, w, b):
        return (matmul_bias_relu_ref(x, w, b, relu=True) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_mxu_utilization_estimate_sane():
    assert mxu_utilization_estimate(128, 64, 128) == pytest.approx(1.0)
    assert mxu_utilization_estimate(32, 64, 52) < 0.2


# ---------------------------------------------------------------------
# Reference-level invariants (used by the model)
# ---------------------------------------------------------------------

def test_embedding_bag_masks_padding():
    emb = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    ids = jnp.array([[[1, 2, 0]]], jnp.int32)  # [1,1,3]
    mask = jnp.array([[[1.0, 1.0, 0.0]]])
    out = embedding_bag_ref(emb, ids, mask)
    np.testing.assert_allclose(out[0, 0], emb[1] + emb[2])


def test_interaction_count_and_symmetry():
    key = jax.random.PRNGKey(1)
    bottom = rand(key, (4, 8))
    pooled = rand(key, (4, 3, 8))
    out = interaction_ref(bottom, pooled)
    assert out.shape == (4, 6)  # (3+1)*3/2


def test_bce_at_zero_logits_is_ln2():
    logits = jnp.zeros((16,))
    labels = jnp.array([0.0, 1.0] * 8)
    assert float(bce_with_logits_ref(logits, labels)) == pytest.approx(
        float(jnp.log(2.0)), rel=1e-6
    )


# ---------------------------------------------------------------------
# Interaction (gram) kernel
# ---------------------------------------------------------------------

from compile.kernels.interaction import gram, interaction  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=20),
    s=st.integers(min_value=1, max_value=9),
    e=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_interaction_matches_ref(b, s, e, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    bottom = rand(k1, (b, e))
    pooled = rand(k2, (b, s, e))
    got = interaction(bottom, pooled)
    want = interaction_ref(bottom, pooled)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gram_is_symmetric():
    key = jax.random.PRNGKey(9)
    cat = rand(key, (6, 5, 8))
    g = gram(cat)
    np.testing.assert_allclose(g, np.swapaxes(g, 1, 2), rtol=1e-6)


def test_interaction_grads_match_ref():
    key = jax.random.PRNGKey(10)
    k1, k2 = jax.random.split(key)
    bottom = rand(k1, (7, 8))
    pooled = rand(k2, (7, 4, 8))

    def f_kernel(b, p):
        return (interaction(b, p) ** 2).sum()

    def f_ref(b, p):
        return (interaction_ref(b, p) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1))(bottom, pooled)
    gr = jax.grad(f_ref, argnums=(0, 1))(bottom, pooled)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-5)
