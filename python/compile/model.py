"""L2: the DLRM forward/backward in JAX, calling the L1 Pallas kernels.

The model consumes exactly what DPP produces (dense matrix + per-feature
id lists + labels) and is the paper's "trainer" compute: dense tower →
embedding bags → dot interaction → top tower → CTR logit (Naumov et al.
DLRM, the architecture the paper's RMs build on).

Shapes are fixed at AOT time (one compiled executable per model variant;
see DESIGN.md). Params travel as a flat tuple so the Rust runtime can
feed/receive them positionally.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.dense_xform import dense_xform
from .kernels.interaction import interaction
from .kernels.mlp import matmul_bias_relu


@dataclass(frozen=True)
class DlrmConfig:
    batch: int = 32
    n_dense: int = 16       # dense features after preprocessing
    n_sparse: int = 8       # sparse features (embedding bags)
    ids_per_feature: int = 16  # L: padded id-list length
    vocab: int = 8192       # hashed id space (SigridHash modulus)
    emb_dim: int = 16       # E
    hidden: int = 64
    lr: float = 0.05

    @property
    def n_interactions(self) -> int:
        s = self.n_sparse + 1
        return s * (s - 1) // 2

    @property
    def top_in(self) -> int:
        return self.emb_dim + self.n_interactions


CFG = DlrmConfig()

# Flat param order (the Rust runtime indexes these positionally).
PARAM_NAMES = (
    "emb",      # [V, E]
    "w_bot1",   # [D, H]
    "b_bot1",   # [H]
    "w_bot2",   # [H, E]
    "b_bot2",   # [E]
    "w_top1",   # [E + I, H]
    "b_top1",   # [H]
    "w_top2",   # [H, 1]
    "b_top2",   # [1]
)


def param_shapes(cfg: DlrmConfig = CFG):
    return (
        (cfg.vocab, cfg.emb_dim),
        (cfg.n_dense, cfg.hidden),
        (cfg.hidden,),
        (cfg.hidden, cfg.emb_dim),
        (cfg.emb_dim,),
        (cfg.top_in, cfg.hidden),
        (cfg.hidden,),
        (cfg.hidden, 1),
        (1,),
    )


def init_params(key, cfg: DlrmConfig = CFG):
    """Glorot-ish init, returned as a flat tuple of f32 arrays."""
    shapes = param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    out = []
    for k, shape in zip(keys, shapes):
        if len(shape) == 2:
            scale = (2.0 / (shape[0] + shape[1])) ** 0.5
            out.append(scale * jax.random.normal(k, shape, jnp.float32))
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return tuple(out)


def num_params(cfg: DlrmConfig = CFG) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for s in param_shapes(cfg))


# Per-feature normalization constants (static: dataset statistics).
_DENSE_MEAN = jnp.zeros((CFG.n_dense,), jnp.float32)
_DENSE_STD = 2.0 * jnp.ones((CFG.n_dense,), jnp.float32)


def forward(params, dense, ids, mask, cfg: DlrmConfig = CFG):
    """DLRM forward: returns logits [B]."""
    (emb, w1, b1, w2, b2, wt1, bt1, wt2, bt2) = params
    # L1 kernel: fused dense normalization.
    x = dense_xform(dense, _DENSE_MEAN, _DENSE_STD)
    # Bottom tower (L1 Pallas matmuls).
    h = matmul_bias_relu(x, w1, b1, relu=True)
    bottom = matmul_bias_relu(h, w2, b2, relu=False)  # [B, E]
    # Embedding bags.
    vecs = emb[ids]                                   # [B, S, L, E]
    pooled = (vecs * mask[..., None]).sum(axis=2)     # [B, S, E]
    # Dot interaction (L1 Pallas gram kernel; triu extracted in jax).
    inter = interaction(bottom, pooled)               # [B, I]
    # Top tower.
    top_in = jnp.concatenate([bottom, inter], axis=1)
    h2 = matmul_bias_relu(top_in, wt1, bt1, relu=True)
    logits = matmul_bias_relu(h2, wt2, bt2, relu=False)[:, 0]
    return logits


def loss_fn(params, dense, ids, mask, labels, cfg: DlrmConfig = CFG):
    logits = forward(params, dense, ids, mask, cfg)
    z = logits
    loss = jnp.mean(
        jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    )
    return loss


def fwd_loss(params_and_batch_flat, cfg: DlrmConfig = CFG):
    """AOT entrypoint: (*params, dense, ids, mask, labels) -> (loss, logits)."""
    params = params_and_batch_flat[: len(PARAM_NAMES)]
    dense, ids, mask, labels = params_and_batch_flat[len(PARAM_NAMES):]
    logits = forward(params, dense, ids, mask, cfg)
    z = logits
    loss = jnp.mean(
        jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    )
    return (loss, logits)


def train_step(*params_and_batch, cfg: DlrmConfig = CFG):
    """AOT entrypoint: one fused fwd+bwd+SGD step.

    (*params, dense, ids, mask, labels) -> (*new_params, loss)
    """
    params = tuple(params_and_batch[: len(PARAM_NAMES)])
    dense, ids, mask, labels = params_and_batch[len(PARAM_NAMES):]
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, dense, ids, mask, labels, cfg)
    )(params)
    new_params = tuple(p - cfg.lr * g for p, g in zip(params, grads))
    return (*new_params, loss)


def batch_spec(cfg: DlrmConfig = CFG):
    """ShapeDtypeStructs for one input batch (after the params)."""
    f32 = jnp.float32
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((cfg.batch, cfg.n_dense), f32),                  # dense
        jax.ShapeDtypeStruct((cfg.batch, cfg.n_sparse, cfg.ids_per_feature), i32),  # ids
        jax.ShapeDtypeStruct((cfg.batch, cfg.n_sparse, cfg.ids_per_feature), f32),  # mask
        jax.ShapeDtypeStruct((cfg.batch,), f32),                              # labels
    )


def param_specs(cfg: DlrmConfig = CFG):
    return tuple(
        jax.ShapeDtypeStruct(s, jnp.float32) for s in param_shapes(cfg)
    )
