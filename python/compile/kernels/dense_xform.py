"""L1 Pallas kernel: fused dense-feature normalization.

The DLRM dense path applies a per-feature normalization pipeline
(paper Table 11: Clamp / Logit / BoxCox-style ops). Done naively this is
several elementwise passes over the [B, D] dense matrix — several HBM
round-trips. This kernel fuses the whole pipeline into one VMEM-resident
pass.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles [B, D] into
(BLOCK_B, BLOCK_D) VPU-aligned blocks (lanes = 128, sublanes = 8);
`mean`/`std` are tiled along D only and broadcast across the batch block.
`interpret=True` everywhere on this image — CPU PJRT cannot run Mosaic
custom-calls; the kernel's *structure* is what carries to real TPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-friendly tile: 8 sublanes x 128 lanes.
BLOCK_B = 8
BLOCK_D = 128


def _fwd_kernel(x_ref, mean_ref, std_ref, o_ref):
    x = x_ref[...]
    mean = mean_ref[...]
    std = std_ref[...]
    z = (x - mean) / std
    y = jnp.sign(z) * jnp.log1p(jnp.abs(z))
    o_ref[...] = jnp.clip(y, -8.0, 8.0)


def _bwd_kernel(x_ref, mean_ref, std_ref, g_ref, o_ref):
    """dL/dx for the fused pipeline: fused elementwise, same tiling."""
    x = x_ref[...]
    mean = mean_ref[...]
    std = std_ref[...]
    g = g_ref[...]
    z = (x - mean) / std
    inner = jnp.sign(z) * jnp.log1p(jnp.abs(z))
    live = (jnp.abs(inner) < 8.0).astype(x.dtype)  # clip pass-through
    o_ref[...] = g * live / (1.0 + jnp.abs(z)) / std


def _tiled_call(kernel, arrs_2d, arrs_1d, b, d, dtype):
    """Run an elementwise kernel over [B, D] blocks with D-tiled vectors."""
    pb = (-b) % BLOCK_B
    pd = (-d) % BLOCK_D
    padded_2d = [jnp.pad(a, ((0, pb), (0, pd))) for a in arrs_2d]
    # Vector pads: std-like vectors pad with 1 to avoid /0 in dead lanes.
    padded_1d = [
        jnp.pad(a, (0, pd), constant_values=cv) for (a, cv) in arrs_1d
    ]
    gb, gd = (b + pb) // BLOCK_B, (d + pd) // BLOCK_D
    out = pl.pallas_call(
        kernel,
        grid=(gb, gd),
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_D), lambda i, j: (i, j))
            for _ in padded_2d[:1]
        ]
        + [
            pl.BlockSpec((BLOCK_D,), lambda i, j: (j,))
            for _ in padded_1d
        ]
        + [
            pl.BlockSpec((BLOCK_B, BLOCK_D), lambda i, j: (i, j))
            for _ in padded_2d[1:]
        ],
        out_specs=pl.BlockSpec((BLOCK_B, BLOCK_D), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(((b + pb), (d + pd)), dtype),
        interpret=True,
    )(padded_2d[0], *padded_1d, *padded_2d[1:])
    return out[:b, :d]


@jax.custom_vjp
def dense_xform(x, mean, std):
    """Fused normalization of a [B, D] dense-feature matrix.

    Pads to block multiples, runs the Pallas grid, slices back — so any
    shape works while the kernel itself stays block-aligned. Reverse-mode
    AD flows through a matching fused Pallas backward kernel.
    """
    b, d = x.shape
    return _tiled_call(
        _fwd_kernel, [x], [(mean, 0.0), (std, 1.0)], b, d, x.dtype
    )


def _dx_fwd(x, mean, std):
    return dense_xform(x, mean, std), (x, mean, std)


def _dx_bwd(res, g):
    x, mean, std = res
    b, d = x.shape
    dx = _tiled_call(
        _bwd_kernel, [x, g], [(mean, 0.0), (std, 1.0)], b, d, x.dtype
    )
    # mean/std are dataset statistics (constants in the model); exact
    # cotangents are cheap reductions of dx.
    z = (x - mean[None, :]) / std[None, :]
    dmean = -dx.sum(axis=0)
    dstd = -(dx * z).sum(axis=0)
    return dx, dmean, dstd


dense_xform.defvjp(_dx_fwd, _dx_bwd)


def vmem_bytes_per_step(dtype_bytes: int = 4) -> int:
    """VMEM working set per grid step (for the DESIGN.md §Perf estimate):
    x block + out block + mean + std tiles."""
    return (2 * BLOCK_B * BLOCK_D + 2 * BLOCK_D) * dtype_bytes
