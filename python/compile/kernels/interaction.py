"""L1 Pallas kernel: DLRM dot-product feature interaction.

Computes the per-sample Gram matrix of the (bottom ⊕ embedding-bag)
vectors — `gram[b] = cat[b] @ cat[b]^T` — the MXU-shaped core of the
DLRM interaction layer. The upper-triangle extraction (a cheap gather)
stays in the surrounding jax.

TPU mapping: the grid walks batch blocks; each step issues one batched
[S+1, E] x [E, S+1] contraction per sample from VMEM. `interpret=True`
as everywhere on this image (see dense_xform.py). Differentiable via a
matching Pallas backward kernel: dcat = (g + g^T) @ cat.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 8


def _fwd_kernel(cat_ref, o_ref):
    cat = cat_ref[...]  # [BB, S1, E]
    o_ref[...] = jnp.einsum(
        "bie,bje->bij", cat, cat, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _bwd_kernel(cat_ref, g_ref, o_ref):
    cat = cat_ref[...]
    g = g_ref[...]  # [BB, S1, S1]
    gsym = g + jnp.swapaxes(g, 1, 2)
    o_ref[...] = jnp.einsum(
        "bij,bje->bie", gsym, cat, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _call(kernel, args, out_shape):
    b = args[0].shape[0]
    pb = (-b) % BLOCK_B
    padded = [jnp.pad(a, ((0, pb),) + ((0, 0),) * (a.ndim - 1)) for a in args]
    gb = (b + pb) // BLOCK_B
    out = pl.pallas_call(
        kernel,
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((BLOCK_B,) + a.shape[1:], lambda i: (i,) + (0,) * (a.ndim - 1))
            for a in padded
        ],
        out_specs=pl.BlockSpec(
            (BLOCK_B,) + out_shape[1:], lambda i: (i,) + (0,) * (len(out_shape) - 1)
        ),
        out_shape=jax.ShapeDtypeStruct((b + pb,) + out_shape[1:], jnp.float32),
        interpret=True,
    )(*padded)
    return out[:b]


@jax.custom_vjp
def gram(cat):
    """Per-sample Gram matrix: [B, S1, E] -> [B, S1, S1]."""
    b, s1, _ = cat.shape
    return _call(_fwd_kernel, [cat], (b, s1, s1))


def _gram_fwd(cat):
    return gram(cat), cat


def _gram_bwd(cat, g):
    b, s1, e = cat.shape
    return (_call(_bwd_kernel, [cat, g], (b, s1, e)),)


gram.defvjp(_gram_fwd, _gram_bwd)


def interaction(bottom, pooled):
    """DLRM interaction: upper-triangle pairwise dots of the S+1 vectors.

    bottom [B, E], pooled [B, S, E] -> [B, S(S+1)/2]
    """
    s = pooled.shape[1]
    cat = jnp.concatenate([bottom[:, None, :], pooled], axis=1)
    gm = gram(cat)
    iu = jnp.triu_indices(s + 1, k=1)
    return gm[:, iu[0], iu[1]]


def vmem_bytes_per_step(s1: int, e: int, dtype_bytes: int = 4) -> int:
    """VMEM per grid step: cat block + gram block."""
    return BLOCK_B * (s1 * e + s1 * s1) * dtype_bytes
