"""L1 Pallas kernel: tiled dense layer (matmul + bias + optional ReLU).

The DLRM bottom/top MLP towers are the model's MXU work. The kernel
tiles the output [M, N] into (BLOCK_M, BLOCK_N) blocks; each grid step
loads an [BLOCK_M, K] x-slab and a [K, BLOCK_N] w-slab into VMEM and
issues one MXU contraction. K is kept whole per step (DLRM tower widths
here are <= 128, so a K-loop with an accumulator would only add
scratch traffic; on larger towers, extend the grid with a K axis and a
VMEM accumulator).

MXU mapping (DESIGN.md §Hardware-Adaptation): BLOCK_M x BLOCK_N = 128 x
128 matches the MXU systolic array; f32 here, bf16 inputs + f32
accumulation on real hardware. `interpret=True` for CPU-PJRT (see
dense_xform.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128


def _kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _mm_impl(x, w, b, relu):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    pm = (-m) % BLOCK_M
    pn = (-n) % BLOCK_N
    xp = jnp.pad(x, ((0, pm), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, pn)))
    bp = jnp.pad(b, (0, pn))
    gm, gn = xp.shape[0] // BLOCK_M, wp.shape[1] // BLOCK_N
    out = pl.pallas_call(
        functools.partial(_kernel, relu=relu),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BLOCK_N), lambda i, j: (0, j)),
            pl.BlockSpec((BLOCK_N,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mm_vjp(x, w, b, relu):
    return _mm_impl(x, w, b, relu)


def _mm_fwd(x, w, b, relu):
    y = _mm_impl(x, w, b, relu)
    return y, (x, w, y if relu else None)


def _mm_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)
    zero_n = jnp.zeros((w.shape[0],), g.dtype)
    zero_k = jnp.zeros((g.shape[1],), g.dtype)
    # Backward matmuls run through the same Pallas kernel (bias 0, no
    # activation): dx = g @ w^T, dw = x^T @ g.
    dx = _mm_impl(g, w.T, zero_n, False)
    dw = _mm_impl(x.T, g, zero_k, False)
    db = g.sum(axis=0)
    return dx, dw, db


_mm_vjp.defvjp(_mm_fwd, _mm_bwd)


def matmul_bias_relu(x, w, b, relu=True):
    """[M, K] @ [K, N] + b with optional ReLU, Pallas-tiled over [M, N].
    Differentiable: backward matmuls reuse the same Pallas kernel."""
    return _mm_vjp(x, w, b, relu)


def vmem_bytes_per_step(k: int, dtype_bytes: int = 4) -> int:
    """VMEM working set per grid step: x slab + w slab + bias + out block."""
    return (
        BLOCK_M * k + k * BLOCK_N + BLOCK_N + BLOCK_M * BLOCK_N
    ) * dtype_bytes


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """Fraction of MXU-issue slots doing useful work for these dims
    (padding waste only; assumes perfect pipelining)."""
    pm = BLOCK_M * -(-m // BLOCK_M)
    pn = BLOCK_N * -(-n // BLOCK_N)
    return (m * k * n) / float(pm * k * pn)
