"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here is straight-line jax.numpy with no Pallas — the ground
truth that `pytest python/tests` compares the kernels against.
"""

import jax.numpy as jnp


def dense_xform_ref(x, mean, std):
    """Fused dense-feature normalization (the DLRM dense path's hot loop).

    Per feature j: z = (x[:, j] - mean[j]) / std[j]; then a signed
    log1p squash and a clamp — the Logit/BoxCox/Clamp-flavored
    normalization pipeline of paper Table 11, fused into one pass.
    """
    z = (x - mean[None, :]) / std[None, :]
    y = jnp.sign(z) * jnp.log1p(jnp.abs(z))
    return jnp.clip(y, -8.0, 8.0)


def matmul_bias_relu_ref(x, w, b, *, relu=True):
    """Dense layer: x @ w + b, optional ReLU."""
    y = x @ w + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def embedding_bag_ref(emb, ids, mask):
    """Per-feature embedding-bag sum.

    emb:  [V, E]
    ids:  [B, S, L] int32 in [0, V)
    mask: [B, S, L] float (1.0 = real id, 0.0 = padding)
    returns [B, S, E]
    """
    vecs = emb[ids]  # [B, S, L, E]
    return (vecs * mask[..., None]).sum(axis=2)


def interaction_ref(bottom, pooled):
    """DLRM dot-product feature interaction.

    bottom: [B, E] (dense tower output)
    pooled: [B, S, E] (embedding bags)
    returns [B, (S+1)S/2] upper-triangle pairwise dots of the S+1
    vectors (excluding self-interactions).
    """
    s = pooled.shape[1]
    cat = jnp.concatenate([bottom[:, None, :], pooled], axis=1)  # [B,S+1,E]
    gram = jnp.einsum("bie,bje->bij", cat, cat)  # [B,S+1,S+1]
    iu = jnp.triu_indices(s + 1, k=1)
    return gram[:, iu[0], iu[1]]


def bce_with_logits_ref(logits, labels):
    """Numerically-stable binary cross entropy on logits."""
    z = logits
    return jnp.mean(
        jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    )
