"""AOT export: lower the L2 DLRM graphs (with their L1 Pallas kernels) to
HLO **text** artifacts the Rust runtime loads via PJRT.

HLO text — NOT `lowered.compile().serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run once via `make artifacts`; Python never runs on the request path.

Outputs (in --out-dir):
  dlrm_fwd.hlo.txt         (*params, batch) -> (loss, logits)
  dlrm_train_step.hlo.txt  (*params, batch) -> (*new_params, loss)
  dense_xform.hlo.txt      standalone L1 kernel (for worker-side offload
                           experiments and runtime smoke tests)
  manifest.txt             key=value interface description for Rust
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.dense_xform import dense_xform
from .model import (CFG, PARAM_NAMES, batch_spec, fwd_loss, num_params,
                    param_shapes, param_specs, train_step)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    pspecs = param_specs()
    bspecs = batch_spec()

    # --- dlrm_fwd: (*params, dense, ids, mask, labels) -> (loss, logits)
    def fwd_entry(*args):
        return fwd_loss(args)

    lowered = jax.jit(fwd_entry).lower(*pspecs, *bspecs)
    path = os.path.join(out_dir, "dlrm_fwd.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # --- dlrm_train_step: fused fwd+bwd+SGD
    lowered = jax.jit(train_step).lower(*pspecs, *bspecs)
    path = os.path.join(out_dir, "dlrm_train_step.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # --- standalone dense_xform kernel
    def dx_entry(x, mean, std):
        return (dense_xform(x, mean, std),)

    spec = jax.ShapeDtypeStruct((CFG.batch, CFG.n_dense), jnp.float32)
    vspec = jax.ShapeDtypeStruct((CFG.n_dense,), jnp.float32)
    lowered = jax.jit(dx_entry).lower(spec, vspec, vspec)
    path = os.path.join(out_dir, "dense_xform.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    print(f"wrote {path}")

    # --- manifest: the positional interface the Rust runtime needs
    lines = [
        f"batch={CFG.batch}",
        f"n_dense={CFG.n_dense}",
        f"n_sparse={CFG.n_sparse}",
        f"ids_per_feature={CFG.ids_per_feature}",
        f"vocab={CFG.vocab}",
        f"emb_dim={CFG.emb_dim}",
        f"hidden={CFG.hidden}",
        f"lr={CFG.lr}",
        f"num_params={num_params()}",
        f"param_tensors={len(PARAM_NAMES)}",
    ]
    for name, shape in zip(PARAM_NAMES, param_shapes()):
        lines.append(f"param.{name}={','.join(str(d) for d in shape)}")
    path = os.path.join(out_dir, "manifest.txt")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export(args.out_dir)


if __name__ == "__main__":
    main()
